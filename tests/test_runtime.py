"""Tests for the unified serving runtime (repro.serving.runtime).

Golden parity: the legacy entry points became EngineCore configurations;
fixed-seed ``simulate`` / ``simulate_batched`` results (accuracy, miss
rate, mean depth, mean confidence, makespan, throughput) must equal the
values the pre-refactor loops produced, for RTDeepIoT, EDF, LCF and RR.
The constants below were recorded by running the original
``repro.core.simulator.simulate`` / ``repro.serving.batch.simulate_batched``
implementations (PR 1 tree) on exactly this workload.

Plus: unified host-cost accounting (the legacy ``simulate_batched``
dropped charged scheduler time), the pipelined dispatch deadline-safety
invariant, the pipelined-vs-synchronous overhead claim on a deterministic
cost model, and wall-clock engine smoke via the runtime.
"""
import numpy as np
import pytest

from repro.core import (EDF, LCF, RR, RTDeepIoT, Task, Workload,
                        make_predictor, simulate)
from repro.serving import ServeSpec, Service
from repro.serving.batch import BatchTimeModel, simulate_batched
from repro.serving.runtime import OracleExecutor, simulate_runtime

STAGE_TIMES = (0.004, 0.007, 0.010)


def oracle_tables(n=600, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def time_model():
    return BatchTimeModel.linear(STAGE_TIMES, (1, 2, 4, 8, 16), marginal=0.15)


def mk_policy(name, conf):
    if name == "rtdeepiot":
        return RTDeepIoT(make_predictor("exp", prior_curve=conf.mean(0)))
    return {"edf": EDF, "lcf": LCF, "rr": RR}[name]()


def golden_workload():
    return Workload(n_clients=24, d_lo=0.01, d_hi=0.3, n_requests=300, seed=0)


# ---------------------------------------------------------------------------
# golden parity: runtime == pre-refactor simulators, bit for bit
# ---------------------------------------------------------------------------

# (accuracy, miss_rate, mean_depth, mean_conf, makespan, throughput) —
# recorded from the pre-refactor loops at the fixed-seed workload above
GOLDEN = {
    ("rtdeepiot", "sim"): (0.5, 0.0, 1.3033333333333332, 0.5391706063341832,
                           1.8434826559500153, 162.7354610751132),
    ("rtdeepiot", "batched"): (0.6666666666666666, 0.02, 1.989795918367347,
                               0.675604053149701, 0.8953826559500192,
                               328.3512340185549),
    ("edf", "sim"): (0.21333333333333335, 0.5833333333333334, 1.384,
                     0.5355863704120423, 2.103482655950014,
                     59.425258224221096),
    ("edf", "batched"): (0.5533333333333333, 0.15666666666666668,
                         1.901185770750988, 0.6378603449394006,
                         2.004632655950016, 126.2076616626305),
    ("lcf", "sim"): (0.5833333333333334, 0.0, 1.33, 0.5507444192248545,
                     1.9854826559500154, 151.09676183822202),
    ("lcf", "batched"): (0.77, 0.01, 2.4175084175084174, 0.7558069680225269,
                         1.3820326559500191, 214.90085543299995),
    ("rr", "sim"): (0.5033333333333333, 0.14333333333333334,
                    1.4980544747081712, 0.5755582324746783,
                    2.0644826559500116, 124.486393363153),
    ("rr", "batched"): (0.74, 0.12333333333333334, 2.722433460076046,
                        0.787734774223797, 1.4284326559500187,
                        184.1178853651204),
}


@pytest.mark.parametrize("policy_name,kind", sorted(GOLDEN))
def test_golden_parity(policy_name, kind):
    conf, correct = oracle_tables()
    pol = mk_policy(policy_name, conf)
    if kind == "sim":
        res = simulate(pol, golden_workload(), STAGE_TIMES, conf, correct)
    else:
        res = simulate_batched(pol, golden_workload(), time_model(), conf,
                               correct)
    acc, miss, depth, mconf, makespan, thr = GOLDEN[(policy_name, kind)]
    assert res.accuracy == pytest.approx(acc, rel=1e-12)
    assert res.miss_rate == pytest.approx(miss, rel=1e-12)
    assert res.mean_depth == pytest.approx(depth, rel=1e-12)
    assert res.mean_conf == pytest.approx(mconf, rel=1e-12)
    assert res.makespan == pytest.approx(makespan, rel=1e-12)
    assert res.throughput == pytest.approx(thr, rel=1e-12)
    assert res.n_requests == 300


@pytest.mark.parametrize("policy_name,kind", sorted(GOLDEN))
def test_golden_parity_via_servespec(policy_name, kind):
    """The same pre-refactor constants, bit for bit, when the engine is
    declared as a ServeSpec (registry-built policy included) and run
    through the Service facade — for all four policies on both
    discrete-event paths.  The spec round-trips through JSON en route."""
    conf, correct = oracle_tables()
    pargs = {"predictor": "exp"} if policy_name == "rtdeepiot" else {}
    if kind == "sim":
        batching = {"mode": "none", "stage_times": list(STAGE_TIMES)}
    else:
        batching = {"buckets": [1, 2, 4, 8, 16], "marginal": 0.15,
                    "stage_times": list(STAGE_TIMES)}
    spec = ServeSpec(policy=policy_name, policy_args=pargs,
                     executor="oracle", clock="virtual",
                     source="closed-loop", batching=batching)
    spec = ServeSpec.from_json(spec.to_json())
    res = Service.from_spec(spec, workload=golden_workload(),
                            conf_table=conf, correct_table=correct).run()
    acc, miss, depth, mconf, makespan, thr = GOLDEN[(policy_name, kind)]
    assert res.accuracy == pytest.approx(acc, rel=1e-12)
    assert res.miss_rate == pytest.approx(miss, rel=1e-12)
    assert res.mean_depth == pytest.approx(depth, rel=1e-12)
    assert res.mean_conf == pytest.approx(mconf, rel=1e-12)
    assert res.makespan == pytest.approx(makespan, rel=1e-12)
    assert res.throughput == pytest.approx(thr, rel=1e-12)
    assert res.n_requests == 300


def test_runtime_native_equals_shims():
    """simulate_runtime(pipeline_depth=1) IS the shims' configuration."""
    conf, correct = oracle_tables()
    tm = time_model()
    r1 = simulate_batched(mk_policy("edf", conf), golden_workload(), tm,
                          conf, correct)
    r2 = simulate_runtime(mk_policy("edf", conf), golden_workload(), tm,
                          conf, correct)
    assert r1.accuracy == r2.accuracy and r1.makespan == r2.makespan
    # identical retirement sequence (tids are a global counter — compare
    # the schedule-relevant fields instead)
    key = lambda f: (f["arrival"], f["deadline"], f["depth"], f["missed"])  # noqa: E731
    assert [key(f) for f in r1.per_request] == \
        [key(f) for f in r2.per_request]


# ---------------------------------------------------------------------------
# unified host-cost accounting (satellite: simulate_batched dropped it)
# ---------------------------------------------------------------------------

def test_charged_time_accounting_parity():
    """With a per-dispatch overhead, BOTH discrete-event paths must report
    the charged host time — the legacy ``simulate_batched.charge()`` threw
    it away.  At max_batch=1 the two paths run the identical schedule, so
    dispatch counts (and the deterministic overhead component) agree."""
    conf, correct = oracle_tables()
    tm1 = BatchTimeModel.linear(STAGE_TIMES, (1,))
    do = 1e-3
    r_u = simulate(mk_policy("edf", conf), golden_workload(), STAGE_TIMES,
                   conf, correct, dispatch_overhead=do)
    r_b = simulate_batched(mk_policy("edf", conf), golden_workload(), tm1,
                           conf, correct, dispatch_overhead=do, max_batch=1)
    assert r_u.n_dispatches == r_b.n_dispatches > 0
    # same schedule → same results
    assert r_u.accuracy == r_b.accuracy
    assert r_u.makespan == r_b.makespan
    # the charged accounting includes every dispatch's overhead on BOTH paths
    assert r_u.sched_charged >= r_u.n_dispatches * do
    assert r_b.sched_charged >= r_b.n_dispatches * do
    # synchronous dispatch: every charged second serialized
    assert r_u.host_serial == pytest.approx(r_u.sched_charged)
    assert r_b.host_serial == pytest.approx(r_b.sched_charged)
    assert r_b.host_overhead_frac > 0.0


def test_charge_overhead_advances_virtual_time():
    """charge_overhead=True must stretch the timeline by the charged host
    time on the batched path too (it did only on the unbatched one)."""
    conf, correct = oracle_tables()
    tm = time_model()
    wl = golden_workload()
    base = simulate_batched(mk_policy("edf", conf), wl, tm, conf, correct,
                            dispatch_overhead=1e-3)
    charged = simulate_batched(mk_policy("edf", conf), wl, tm, conf, correct,
                               dispatch_overhead=1e-3, charge_overhead=True)
    assert charged.makespan > base.makespan


# ---------------------------------------------------------------------------
# pipelined async dispatch
# ---------------------------------------------------------------------------

class InvariantCheckingExecutor(OracleExecutor):
    """Asserts the PR-1 deadline-safety invariant at every submit: no
    co-runner admitted into a batch may be pushed past its deadline by the
    batch's bucket-rounded WCET (the leader keeps the legacy
    dispatch-anyway singleton semantics), and every member runs its actual
    next stage."""

    def __init__(self, time_model, conf_table):
        super().__init__(time_model, conf_table)
        self.checked = 0

    def submit(self, stage, tasks, now):
        w = self.time_model.wcet(stage, len(tasks))
        for i, t in enumerate(tasks):
            assert t.executed == stage
            assert t.executed < t.assigned_depth
            if i > 0:
                assert t.fits_batch(now, w), \
                    f"co-runner past deadline: slack={t.slack(now)} w={w}"
        self.checked += 1
        super().submit(stage, tasks, now)


def test_pipelined_dispatch_keeps_deadline_invariant():
    """Overloaded closed loop, pipeline_depth=2: every dispatched batch —
    pre-selected, re-validated, topped off — satisfies the batching
    deadline invariant at TRUE dispatch time, and pre-selection actually
    gets used.  The checking executor rides into the Service as a
    component-instance resource."""
    conf, correct = oracle_tables()
    tm = time_model()
    wl = Workload(n_clients=48, d_lo=0.01, d_hi=0.25, n_requests=400, seed=2)
    ex = InvariantCheckingExecutor(tm, conf)
    spec = ServeSpec(policy="rtdeepiot", policy_args={"predictor": "exp"},
                     executor="oracle", clock="virtual", source="closed-loop",
                     pipeline_depth=2, dispatch_overhead=1e-4,
                     policy_cost=5e-4, charge_overhead=True)
    res = Service.from_spec(spec, executor=ex, time_model=tm, workload=wl,
                            conf_table=conf, correct_table=correct).run()
    assert ex.checked == res.n_dispatches > 0
    assert res.presel_hits > 0
    assert res.n_requests == 400
    assert res.host_serial < res.sched_charged   # some host work was hidden


def test_pipelined_strictly_lower_host_overhead():
    """The async-figure claim, deterministically (modeled host costs):
    pipeline_depth=2 shows a strictly lower charged host-overhead fraction
    than synchronous batched dispatch at equal-or-better accuracy and miss
    rate, K >= 16."""
    conf, correct = oracle_tables()
    tm = time_model()
    for k in (16, 64):
        wl = Workload(n_clients=k, d_lo=0.01, d_hi=0.3, n_requests=600,
                      seed=0)
        kw = dict(charge_overhead=True, dispatch_overhead=1e-4,
                  policy_cost=5e-4)
        r_sync = simulate_runtime(mk_policy("rtdeepiot", conf), wl, tm, conf,
                                  correct, pipeline_depth=1, **kw)
        r_async = simulate_runtime(mk_policy("rtdeepiot", conf), wl, tm, conf,
                                   correct, pipeline_depth=2, **kw)
        assert r_async.host_overhead_frac < r_sync.host_overhead_frac, k
        assert r_async.accuracy >= r_sync.accuracy, k
        assert r_async.miss_rate <= r_sync.miss_rate, k
        # goodput stays within noise of synchronous (fewer misses, but a
        # slightly longer makespan can trade off completed-requests/s)
        assert r_async.throughput >= 0.97 * r_sync.throughput, k


def test_pipelined_noop_without_host_cost():
    """With zero modeled host cost the pipelined schedule cannot be worse
    than synchronous on goodput-relevant metrics (same device model; the
    only difference is when the policy looks at the queue)."""
    conf, correct = oracle_tables()
    tm = time_model()
    wl = Workload(n_clients=16, d_lo=0.02, d_hi=0.3, n_requests=300, seed=1)
    r_s = simulate_runtime(mk_policy("edf", conf), wl, tm, conf, correct,
                           pipeline_depth=1, policy_cost=0.0)
    r_a = simulate_runtime(mk_policy("edf", conf), wl, tm, conf, correct,
                           pipeline_depth=2, policy_cost=0.0)
    assert r_a.miss_rate <= r_s.miss_rate + 0.02
    assert r_a.accuracy >= r_s.accuracy - 0.02


# ---------------------------------------------------------------------------
# wall-clock engines through the runtime (real model, real stage fns)
# ---------------------------------------------------------------------------

@pytest.mark.slow                    # jax compile dominates; no 20x repeat
@pytest.mark.wallclock
@pytest.mark.parametrize("pipelined", [False, True])
def test_wall_clock_batched_engine_serves_all(pipelined):
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import closed_loop_stream
    from repro.training import DifficultyDataset

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(30, seed=9)
    # analytic time model: scheduling decisions only need plausible prices
    tm = BatchTimeModel.linear((0.002, 0.003, 0.004), (1, 2, 4),
                               marginal=0.25)
    spec = ServeSpec(policy="rtdeepiot",
                     policy_args={"predictor": "exp",
                                  "prior_curve": [.5, .7, .85]},
                     executor="device-batched", clock="wall", source="stream",
                     pipeline_depth=2 if pipelined else 1)
    svc = Service.from_spec(spec, cfg=cfg, params=params, time_model=tm)
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=4,
                                d_lo=0.2, d_hi=0.5, n_requests=10, seed=1)
    svc.run(stream)
    responses = svc.responses
    assert len(responses) == 10
    done = [r for r in responses if not r.missed]
    assert len(done) >= 7            # generous deadlines: most complete
    for r in done:
        assert 1 <= r.depth <= cfg.num_stages
        assert 0.0 <= r.confidence <= 1.0


# ---------------------------------------------------------------------------
# custom single-shot source injected into the Service as a resource
# ---------------------------------------------------------------------------

def test_engine_core_drains_unfinished_tasks_at_deadline():
    """A task the policy never schedules (infeasible) retires at its
    deadline and extends the makespan — Fig. 2 drain semantics."""
    conf, correct = oracle_tables(n=4)
    tm = BatchTimeModel.linear((0.2, 0.2, 0.2), (1,))

    class OneShotSource:
        def __init__(self):
            self.sent = False

        def has_pending(self):
            return not self.sent

        def next_time(self):
            return 0.0 if not self.sent else np.inf

        def pop(self, now):
            self.sent = True
            return Task(arrival=now, deadline=now + 0.1,
                        stage_times=(0.2, 0.2, 0.2), mandatory=1, sample=0)

        def on_retire(self, task, now):
            pass

    spec = ServeSpec(policy="rtdeepiot",
                     policy_args={"predictor": "exp",
                                  "prior_curve": [0.5, 0.7, 0.9]},
                     executor="oracle", clock="virtual", source="stream",
                     batching={"max_batch": 1})
    res = Service.from_spec(spec, source=OneShotSource(), time_model=tm,
                            conf_table=conf, correct_table=correct).run()
    assert res.n_requests == 1
    assert res.per_request[0]["missed"]
    assert res.makespan == pytest.approx(0.1)
