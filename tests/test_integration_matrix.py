"""Cross-subsystem integration matrix: traffic scenarios x {plain
engine, predictive admission, multi-model zoo, multi-tenant front door}
x {obs tracing on, off} — every cell must schedule **bit-for-bit
deterministically** on the virtual clock across two identical runs.

The matrix is the regression net under the adaptive-control work: the
subsystems compose through one Service facade, so a nondeterministic
iteration order, a wall-clock read, or a fitted-forecast float leak in
any layer shows up here as a signature mismatch.  Rows are compared by
content (offset/sample/slo/model/tenant/depth/outcome), never by ``tid``
— task ids come from a process-global counter and differ between runs by
design.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving import Request, Service
from repro.serving.traffic import (admission_signature, arrival_signature,
                                   scenario_spec)

STAGE_TIMES = (0.004, 0.007, 0.010)

LLM_TIMES = (0.006, 0.010, 0.014)
VISION_TIMES = (0.003, 0.005, 0.007)
ZOO = {
    "llm": {"stage_times": list(LLM_TIMES), "weight": 2.0},
    "vision": {"stage_times": list(VISION_TIMES)},
}
MIX_STAGE_TIMES = tuple(0.4 * a + 0.6 * b
                        for a, b in zip(LLM_TIMES, VISION_TIMES))


def oracle_tables(n=200, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def zoo_tables(models=("llm", "vision"), n=200, L=3, seed=0):
    out = {}
    for i, model in enumerate(sorted(models)):
        rng = np.random.default_rng(seed + i)
        conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
        out[model] = {"conf": conf,
                      "correct": rng.uniform(size=(n, L)) < conf}
    return out


def row_key(r):
    """Replay-comparable content of one per_request row (no tid)."""
    return (round(float(r["offset"]), 9), r["sample"], r.get("slo"),
            r.get("model"), r.get("tenant"), r["depth"], bool(r["missed"]),
            bool(r["rejected"]), r.get("depth_cap"),
            round(float(r["conf"]), 9), round(float(r["latency"]), 9),
            round(float(r["deadline"]), 9))


def signatures(res):
    return (arrival_signature(res.per_request),
            admission_signature(res.per_request),
            sorted(row_key(r) for r in res.per_request))


# ---------------------------------------------------------------------------
# the matrix
# ---------------------------------------------------------------------------

NOMINAL = 1.0 / sum(STAGE_TIMES)
FORECAST = {"process": {"kind": "flash-crowd", "base_rate": 0.7 * NOMINAL,
                        "spike_rate": 3.5 * NOMINAL, "spike_at": 1.9,
                        "spike_len": 1.6},
            "horizon": 0.25}

#: (id, scenario, spec overrides) — each also runs with tracing on
MATRIX = [
    ("steady-plain", "steady",
     dict(policy="rtdeepiot", admission={"mode": "depth_cap"})),
    ("overload-reject", "2x-overload",
     dict(policy="rtdeepiot", admission={"mode": "reject"})),
    ("diurnal-weighted", "diurnal",
     dict(policy="rtdeepiot-weighted", admission={"mode": "depth_cap"})),
    ("flash-forecast", "flash-crowd",
     dict(policy="rtdeepiot-adaptive",
          admission={"mode": "depth_cap", "forecast": FORECAST})),
]


def run_scenario(scenario, overrides, trace, resources=None, stage_times=None):
    spec = scenario_spec(scenario, stage_times=stage_times or STAGE_TIMES,
                         n_requests=80, seed=7, **overrides)
    if trace:
        spec = dataclasses.replace(spec, trace={"enabled": True})
    if resources is None:
        conf, correct = oracle_tables()
        resources = dict(conf_table=conf, correct_table=correct)
    return Service.from_spec(spec, **resources).run()


@pytest.mark.parametrize("trace", [False, True], ids=["raw", "traced"])
@pytest.mark.parametrize("name,scenario,overrides",
                         MATRIX, ids=[m[0] for m in MATRIX])
def test_matrix_cell_is_bitwise_deterministic(name, scenario, overrides,
                                              trace):
    a = run_scenario(scenario, overrides, trace)
    b = run_scenario(scenario, overrides, trace)
    assert a.n_requests == b.n_requests == 80
    assert signatures(a) == signatures(b)


@pytest.mark.parametrize("name,scenario,overrides",
                         MATRIX, ids=[m[0] for m in MATRIX])
def test_tracing_never_perturbs_scheduling(name, scenario, overrides):
    """Observability is read-only: the traced run's schedule is the raw
    run's schedule, bit for bit."""
    raw = run_scenario(scenario, overrides, trace=False)
    traced = run_scenario(scenario, overrides, trace=True)
    assert signatures(raw) == signatures(traced)


@pytest.mark.parametrize("trace", [False, True], ids=["raw", "traced"])
def test_zoo_model_mix_cell_is_bitwise_deterministic(trace):
    tables = zoo_tables()
    runs = []
    for _ in range(2):
        spec = scenario_spec("model-mix", policy="rtdeepiot-zoo",
                             admission={"mode": "depth_cap",
                                        "forecast": FORECAST},
                             stage_times=MIX_STAGE_TIMES, n_requests=80,
                             seed=7, models=ZOO)
        spec = dataclasses.replace(spec, executor="zoo-oracle")
        if trace:
            spec = dataclasses.replace(spec, trace={"enabled": True})
        runs.append(Service.from_spec(
            spec, zoo_tables=tables,
            n_samples=tables["llm"]["conf"].shape[0]).run())
    a, b = runs
    assert {r["model"] for r in a.per_request} == {"llm", "vision"}
    assert signatures(a) == signatures(b)


@pytest.mark.parametrize("trace", [False, True], ids=["raw", "traced"])
def test_frontdoor_tenant_cell_is_bitwise_deterministic(trace):
    from repro.serving import ServeSpec
    conf, correct = oracle_tables()

    def run_once():
        spec = ServeSpec(
            policy="rtdeepiot", executor="oracle", clock="virtual",
            source="frontdoor",
            source_args={"discipline": "drr", "run_queue": 2},
            batching={"mode": "none", "stage_times": list(STAGE_TIMES)},
            slo_classes={"gold": {"rel_deadline": 0.2}},
            default_slo="gold",
            tenants={"gold": {"weight": 5.0}, "free": {"weight": 1.0}},
            trace={"enabled": True} if trace else {})
        svc = Service.from_spec(spec, conf_table=conf,
                                correct_table=correct)
        for i in range(30):
            svc.submit(Request(None, sample=i),
                       tenant="gold" if i % 2 else "free",
                       request_id=f"r{i:03d}", at=i * 0.003)
        return svc.drain()

    a, b = run_once(), run_once()
    assert a.per_tenant.keys() == b.per_tenant.keys() == {"gold", "free"}
    assert signatures(a) == signatures(b)
