"""Tests for the kernel-backed serving fast path (repro.launch.kernel).

Four contracts, alongside tests/test_kernels.py's per-kernel sweeps:

* **Padded-batch parity** — every Pallas kernel, run in interpret mode at
  the exact batch shapes the serving engine dispatches (the pad_batch
  buckets, padding rows replicating the last valid row), must return the
  same *valid* rows as its pure-jnp oracle on the unpadded inputs: the
  bucket discipline never contaminates real requests.
* **Fused exit-confidence exactness** — ``exit_stats_fused`` (one Pallas
  dispatch, logits never materialized) is bit-for-bit equal to the
  unfused reference on the anytime classifier (single vocab block).
* **Ragged decode exactness** — co-batched decode through the kernel
  route (per-row slot_pos) equals per-request singleton runs bitwise,
  at ragged positions where the legacy jnp route (which shares row 0's
  slot map) is not exact.
* **Serving integration** — ``executor="device-kernel"`` matches
  ``device-batched`` predictions/depths end to end; length buckets
  gate batch formation; ``pipeline_depth >= 3`` stacks device windows;
  spec validation rejects malformed args at spec time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.launch.serve  # noqa: F401 — registers device-kernel
from repro.core.task import Task
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.exit_confidence import exit_confidence, exit_confidence_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.mlstm_chunk import mlstm_chunk, mlstm_chunk_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.launch.kernel import (KernelStageFns, build_kernel_executor,
                                 length_bucketed_time_model)
from repro.serving import (BatchTimeModel, LengthBucketTimeModel, ServeSpec,
                           Service, closed_loop_stream)
from repro.serving.batch.batcher import StageBatcher
from repro.serving.batch.time_model import (batch_wcet, len_bucket_for,
                                            task_len_bucket)

SERVING_BUCKETS = (1, 2, 4, 8, 16)
STAGE_TIMES = (0.002, 0.003, 0.004)


def _pad_rows(x, bucket):
    """Serving-style padding: replicate the last valid row to the bucket."""
    reps = np.concatenate([x] + [x[-1:]] * (bucket - x.shape[0]), axis=0)
    return jnp.asarray(reps)


# ---------------------------------------------------------------------------
# padded-batch kernel/ref parity at serving bucket sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bucket", SERVING_BUCKETS)
def test_rmsnorm_padded_batch_parity(bucket):
    n = min(3, bucket)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (n, 64))
    s = 0.1 * jax.random.normal(ks[1], (64,))
    out = rmsnorm(_pad_rows(np.asarray(x), bucket), s, block_rows=8)
    np.testing.assert_allclose(np.asarray(out[:n]),
                               np.asarray(rmsnorm_ref(x, s)),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("bucket", SERVING_BUCKETS)
def test_exit_confidence_padded_batch_parity(bucket):
    n = min(3, bucket)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    h = jax.random.normal(ks[0], (n, 32))
    sc = 0.1 * jax.random.normal(ks[1], (32,))
    w = 0.3 * jax.random.normal(ks[2], (32, 10))
    conf, pred, m, lse = exit_confidence(_pad_rows(np.asarray(h), bucket),
                                         sc, w, block_rows=4)
    rc, rp, rm, rl = exit_confidence_ref(h, sc, w)
    np.testing.assert_allclose(np.asarray(conf[:n]), np.asarray(rc),
                               atol=2e-6, rtol=2e-6)
    assert np.array_equal(np.asarray(pred[:n]), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(lse[:n]), np.asarray(rl),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bucket", (1, 2, 4, 8))
def test_flash_attention_padded_batch_parity(bucket):
    n = min(3, bucket)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (n, 4, 32, 16))
    k = jax.random.normal(ks[1], (n, 2, 32, 16))
    v = jax.random.normal(ks[2], (n, 2, 32, 16))
    pq, pk, pv = (_pad_rows(np.asarray(t), bucket) for t in (q, k, v))
    out = flash_attention(pq, pk, pv, causal=True, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out[:n]), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bucket", (1, 2, 4, 8))
def test_decode_attention_padded_batch_parity(bucket):
    n = min(3, bucket)
    S = 24
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (n, 4, 16))
    kc = jax.random.normal(ks[1], (n, 2, S, 16))
    vc = jax.random.normal(ks[2], (n, 2, S, 16))
    sp = np.broadcast_to(np.arange(S), (n, S)).copy()
    cur = np.array([5, 11, 23][:n])
    out = decode_attention(_pad_rows(np.asarray(q), bucket),
                           _pad_rows(np.asarray(kc), bucket),
                           _pad_rows(np.asarray(vc), bucket),
                           _pad_rows(sp, bucket), _pad_rows(cur, bucket),
                           block_k=8)
    ref = decode_attention_ref(q, kc, vc, jnp.asarray(sp), jnp.asarray(cur))
    np.testing.assert_allclose(np.asarray(out[:n]), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bucket", (1, 2, 4, 8))
def test_mlstm_chunk_padded_batch_parity(bucket):
    n = min(2, bucket)
    L, dh = 16, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (n, 2, L, dh))
    k = jax.random.normal(ks[1], (n, 2, L, dh))
    v = jax.random.normal(ks[2], (n, 2, L, dh))
    ip = jax.random.normal(ks[3], (n, 2, L))
    fp = jax.random.normal(ks[4], (n, 2, L)) + 2
    C0 = jnp.zeros((n, 2, dh, dh))
    n0 = jnp.zeros((n, 2, dh))
    m0 = jnp.full((n, 2), -1e30)
    padded = [_pad_rows(np.asarray(t), bucket)
              for t in (q, k, v, ip, fp, C0, n0, m0)]
    out = mlstm_chunk(*padded)
    ref = mlstm_chunk_ref(q, k, v, ip, fp, C0, n0, m0)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o[:n]), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# fused exit epilogue: bit-for-bit vs the unfused reference
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("anytime-classifier")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("bucket", (1, 4, 16))
def test_fused_exit_stats_bitwise_equal_unfused(tiny_model, bucket):
    """Single vocab block => the kernel's online pass folds exactly once:
    conf/pred/max/lse all bit-for-bit equal to the materialized-logits
    reference — the kernel-serving figure's exactness claim."""
    from repro.models import exit_rows, exit_stats_fused, exit_stats_unfused
    cfg, params = tiny_model
    h = jax.random.normal(jax.random.PRNGKey(7), (bucket, 16, cfg.d_model))
    rows = exit_rows(cfg, h)
    for s in range(cfg.num_stages):
        scale = params["exits"][s]["ln"]
        w_out = params["exit_shared"]["w_out"]
        fused = exit_stats_fused(rows, scale, w_out, eps=cfg.norm_eps)
        ref = exit_stats_unfused(rows, scale, w_out, eps=cfg.norm_eps)
        for f, r in zip(fused, ref):
            assert np.array_equal(np.asarray(f), np.asarray(r))


def test_kernel_stage_fns_fused_outputs(tiny_model):
    """KernelStageFns returns (h, pred, conf) with pred/conf equal to the
    unfused epilogue applied to the same trunk output."""
    from repro.models import exit_rows, exit_stats_unfused, stage_trunk
    cfg, params = tiny_model
    fns = KernelStageFns(cfg, (1, 2, 4))
    x = {"features": jnp.asarray(
        np.random.default_rng(0).normal(size=(1, 16, 32)), jnp.float32)}
    h, pred, conf, mask = fns.run(0, params, [x])
    # the unfused epilogue on the *same* trunk output must agree bitwise
    # (the fused/unfused claim); the trunk itself matches the eager
    # stage_trunk up to jit fusion reassociation
    rc, rp, _m, _l = exit_stats_unfused(exit_rows(cfg, h),
                                        params["exits"][0]["ln"],
                                        params["exit_shared"]["w_out"],
                                        eps=cfg.norm_eps)
    h_ref = stage_trunk(cfg, params, 0, x, mode="train")
    np.testing.assert_allclose(np.asarray(h[:1]), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)
    assert int(pred[0]) == int(rp[0])
    assert float(conf[0]) == float(rc[0])
    assert mask.tolist() == [True]


def test_kernel_stage_fns_rejects_audio_head():
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="audio-x", arch_type="dense", source="test",
                      num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      head_dim=16, d_ff=64, vocab_size=16, period=("attn",),
                      modality="audio_stub", num_stages=1, stage_ends=(2,))
    with pytest.raises(ValueError, match="audio"):
        KernelStageFns(cfg, (1, 2))


# ---------------------------------------------------------------------------
# length-bucketed WCET pricing
# ---------------------------------------------------------------------------

def test_len_bucket_for_rounds_up():
    assert len_bucket_for(1, (16, 64)) == 16
    assert len_bucket_for(16, (16, 64)) == 16
    assert len_bucket_for(17, (16, 64)) == 64
    with pytest.raises(ValueError):
        len_bucket_for(65, (16, 64))
    with pytest.raises(ValueError):
        len_bucket_for(0, (16, 64))


def test_length_bucket_time_model_pricing():
    tm = LengthBucketTimeModel.linear(STAGE_TIMES, (1, 2, 4),
                                      len_buckets=(16, 64), len_marginal=0.5)
    # length-blind == worst case == largest length bucket
    for s in range(3):
        assert tm.wcet(s, 2) == tm.wcet(s, 2, seq_len=64)
        assert tm.wcet(s, 2, seq_len=10) < tm.wcet(s, 2, seq_len=64)
        # floor: the shortest bucket still costs len_marginal +
        # (1 - len_marginal) * 16/64 of the base
        base = BatchTimeModel.linear(STAGE_TIMES, (1, 2, 4))
        assert tm.wcet(s, 2, seq_len=16) == pytest.approx(
            base.wcet(s, 2) * (0.5 + 0.5 * 16 / 64))


def test_length_bucket_time_model_validates_base_is_max():
    tm = LengthBucketTimeModel.linear(STAGE_TIMES, (1, 2), len_buckets=(8, 32))
    with pytest.raises(ValueError, match="max over length"):
        LengthBucketTimeModel(buckets=tm.buckets,
                              times=tuple(tuple(t * 0.5 for t in row)
                                          for row in tm.times),
                              len_buckets=tm.len_buckets, times3=tm.times3)
    with pytest.raises(ValueError, match="ascending"):
        LengthBucketTimeModel(buckets=tm.buckets, times=tm.times,
                              len_buckets=(32, 8), times3=tm.times3)


def test_length_bucketed_refinement_preserves_blind_pricing():
    base = BatchTimeModel.linear(STAGE_TIMES, (1, 2, 4), marginal=0.25)
    tm = length_bucketed_time_model(base, (16, 64), len_marginal=0.25)
    assert isinstance(tm, LengthBucketTimeModel)
    assert tm.times == base.times          # length-blind consumers unchanged
    assert length_bucketed_time_model(tm, (8,)) is tm   # idempotent
    for s in range(3):
        for n in (1, 3):
            assert tm.wcet(s, n) == base.wcet(s, n)
            assert tm.wcet(s, n, seq_len=64) == base.wcet(s, n)


def test_batch_wcet_and_task_len_bucket():
    tm = LengthBucketTimeModel.linear(STAGE_TIMES, (1, 2, 4),
                                      len_buckets=(16, 64))
    mk = lambda sl: Task(arrival=0.0, deadline=1.0, stage_times=STAGE_TIMES,
                         seq_len=sl)
    short, long, blind = mk(8), mk(40), mk(None)
    assert task_len_bucket(tm, short) == 16
    assert task_len_bucket(tm, long) == 64
    assert task_len_bucket(tm, blind) is None
    # all-lengths batch prices at the max member length
    assert batch_wcet(tm, 0, [short, long]) == tm.wcet(0, 2, seq_len=40)
    # any length-blind member => conservative (worst-length) pricing
    assert batch_wcet(tm, 0, [short, blind]) == tm.wcet(0, 2)


def test_stage_batcher_filters_by_length_bucket():
    tm = LengthBucketTimeModel.linear((0.002,), (1, 2, 4),
                                      len_buckets=(16, 64))
    b = StageBatcher(tm)
    mk = lambda tid, sl: Task(arrival=0.0, deadline=10.0,
                              stage_times=(0.002,), tid=tid, seq_len=sl)
    t_short = [mk(0, 8), mk(1, 12)]
    t_long = [mk(2, 40)]
    batch = b.form(t_short[0], t_short + t_long, now=0.0)
    assert set(t.tid for t in batch) == {0, 1}      # long excluded
    batch = b.form(t_long[0], t_short + t_long, now=0.0)
    assert [t.tid for t in batch] == [2]
    # a length-blind leader batches anyone (worst-case pricing)
    blind = [mk(i + 10, None) for i in range(2)]
    batch = b.form(blind[0], blind, now=0.0)
    assert len(batch) == 2


# ---------------------------------------------------------------------------
# ragged decode batching: kernel route bitwise vs singleton runs
# ---------------------------------------------------------------------------

def _decode_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-decode-test", arch_type="dense",
                       source="test", num_layers=4, d_model=64, num_heads=4,
                       num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=32,
                       period=("attn",), ffn_type="swiglu", modality="text",
                       causal=True, num_stages=2, mandatory_stages=1,
                       stage_ends=(2, 4), dtype="float32")


def test_ragged_decode_batch_bitwise_equals_singletons():
    """Co-batched decode at ragged positions through the Pallas route is
    bitwise equal to running each request alone — the exactness the
    per-row slot_pos map buys (the legacy jnp route shares row 0's)."""
    from repro.launch.kernel import KernelDecodeStageFns
    from repro.launch.mesh import make_serving_mesh
    from repro.models import (ParallelCtx, concat_decode_caches,
                              init_decode_cache, init_params,
                              slice_decode_cache)
    cfg = _decode_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    ctx = ParallelCtx(mesh=make_serving_mesh(1, 1), decode_attn="kernel")
    fns = KernelDecodeStageFns(cfg, (1, 2, 4), ctx)
    rng = np.random.default_rng(0)
    S = 16
    # three requests at ragged positions over a shared slot count
    positions, states = [3, 9, 14], []
    for i, pos in enumerate(positions):
        cache = init_decode_cache(cfg, 1, S)
        for p in range(pos):                       # warm to position pos
            tok = jnp.array([int(rng.integers(cfg.vocab_size))], jnp.int32)
            h = tok
            for s in range(cfg.num_stages):
                h, c, _pred, _conf = fns.fn(s)(
                    params, h, cache[s], jnp.full((1,), p, jnp.int32))
                cache[s] = c
        tok = jnp.array([int(rng.integers(cfg.vocab_size))], jnp.int32)
        states.append({"h": tok, "cache": cache,
                       "cur_pos": jnp.full((1,), pos, jnp.int32)})
    # batched pass
    h_b = jnp.concatenate([st["h"] for st in states])
    cur_b = jnp.concatenate([st["cur_pos"] for st in states])
    outs_b = []
    for s in range(cfg.num_stages):
        cache_b = concat_decode_caches([st["cache"][s] for st in states])
        h_b, cache_sb, pred_b, conf_b = fns.fn(s)(params, h_b, cache_b, cur_b)
        outs_b.append((h_b, cache_sb, pred_b, conf_b))
    # singleton passes must match bitwise
    for i, st in enumerate(states):
        h = st["h"]
        for s in range(cfg.num_stages):
            h, c, pred, conf = fns.fn(s)(params, h, st["cache"][s],
                                         st["cur_pos"])
            h_bs, cache_sb, pred_b, conf_b = outs_b[s]
            assert np.array_equal(np.asarray(h), np.asarray(h_bs[i:i + 1]))
            assert int(pred[0]) == int(pred_b[i])
            assert float(conf[0]) == float(conf_b[i])
            row = slice_decode_cache(cache_sb, i)
            for a, b in zip(jax.tree.leaves(c), jax.tree.leaves(row)):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_slice_concat_decode_cache_roundtrip():
    from repro.models import (concat_decode_caches, init_decode_cache,
                              slice_decode_cache)
    cfg = _decode_cfg()
    cache = init_decode_cache(cfg, 3, 8)
    rows = [slice_decode_cache(cache[0], i) for i in range(3)]
    back = concat_decode_caches(rows)
    for a, b in zip(jax.tree.leaves(cache[0]), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving integration: device-kernel through the registry
# ---------------------------------------------------------------------------

def _stream_spec(executor, executor_args, depth=1):
    return ServeSpec(
        policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        executor=executor, executor_args=executor_args,
        clock="virtual", source="stream", pipeline_depth=depth,
        batching={"buckets": [1, 2, 4], "stage_times": list(STAGE_TIMES),
                  "marginal": 0.25})


def _classifier_stream(cfg, n_requests=12):
    from repro.training import DifficultyDataset
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(30, seed=9)
    return list(closed_loop_stream(test["inputs"], test["labels"],
                                   n_clients=4, d_lo=0.2, d_hi=0.5,
                                   n_requests=n_requests, seed=1))


def test_device_kernel_matches_batched_predictions(tiny_model):
    cfg, params = tiny_model
    stream = _classifier_stream(cfg)
    runs = {}
    for ex in ("device-batched", "device-kernel"):
        svc = Service.from_spec(_stream_spec(ex, {}), cfg=cfg, params=params)
        svc.run(list(stream))
        runs[ex] = svc
    key = lambda svc: [(r.sample, r.prediction, r.depth, r.missed)
                       for r in svc.responses]
    assert key(runs["device-kernel"]) == key(runs["device-batched"])
    np.testing.assert_allclose(
        [r.confidence for r in runs["device-kernel"].responses],
        [r.confidence for r in runs["device-batched"].responses],
        rtol=1e-6)


def test_device_kernel_deep_pipeline_stacks_windows(tiny_model):
    cfg, params = tiny_model
    stream = _classifier_stream(cfg)
    svc = Service.from_spec(_stream_spec("device-kernel", {}, depth=3),
                            cfg=cfg, params=params)
    res = svc.run(list(stream))
    ex = svc.executor
    assert ex.max_inflight == 2            # pipeline_depth - 1 windows
    assert res.n_requests == 12
    assert len(ex._inflight) == 0          # fully drained
    stats = ex.device_time_stats()
    assert stats["host_time"] > 0 and stats["device_time"] > 0
    assert set(stats["stage_host_time"]) == set(stats["stage_device_time"])
    assert ex.cache_stats() == dict(live=0, peak=ex.peak_cached, evictions=12)


def test_service_metrics_surface_device_telemetry(tiny_model):
    """ServiceMetrics carries the executor's measured host/device split
    and cache lifecycle; modeled (oracle) runs report empty dicts."""
    cfg, params = tiny_model
    svc = Service.from_spec(_stream_spec("device-kernel", {}), cfg=cfg,
                            params=params)
    res = svc.run(_classifier_stream(cfg, n_requests=6))
    assert res.executor_times["host_time"] > 0
    assert res.executor_times["device_time"] > 0
    assert set(res.executor_times["stage_host_time"]) == {0, 1, 2} \
        or len(res.executor_times["stage_host_time"]) >= 1
    assert res.executor_cache == dict(live=0, peak=svc.executor.peak_cached,
                                      evictions=6)
    import json
    json.loads(res.to_json())                  # telemetry stays JSON-able
    # oracle executor: no device telemetry
    spec = ServeSpec(policy="edf", clock="virtual", source="stream",
                     batching={"mode": "none",
                               "stage_times": list(STAGE_TIMES)})
    import numpy as np_
    rng = np_.random.default_rng(0)
    conf = np_.sort(rng.uniform(0.5, 1.0, (10, 3)), axis=1)
    correct = rng.uniform(size=(10, 3)) < conf
    svc2 = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    from repro.serving.engine import Request
    res2 = svc2.run([(0.0, Request(inputs=None, sample=0,
                                   rel_deadline=1.0))])
    assert res2.executor_times == {} and res2.executor_cache == {}


def test_executor_telemetry_fresh_across_repeated_runs(tiny_model):
    """The virtual clock never hides device telemetry (host/device time
    is wall-measured), and each run() on one Service rebuilds the
    executor — per-run cache stats never accumulate across runs."""
    cfg, params = tiny_model
    svc = Service.from_spec(_stream_spec("device-batched", {}), cfg=cfg,
                            params=params)
    res1 = svc.run(_classifier_stream(cfg, n_requests=6))
    res2 = svc.run(_classifier_stream(cfg, n_requests=4))
    for res, n in ((res1, 6), (res2, 4)):
        assert res.n_requests == n
        assert res.executor_times["host_time"] > 0
        assert res.executor_times["device_time"] > 0
        assert len(res.executor_times["stage_host_time"]) >= 1
        # every request's hidden state was cached and evicted this run
        assert res.executor_cache["live"] == 0
        assert res.executor_cache["evictions"] == n


def test_device_kernel_refines_time_model_with_len_buckets(tiny_model):
    cfg, params = tiny_model
    svc = Service.from_spec(
        _stream_spec("device-kernel", {"len_buckets": [16, 64]}),
        cfg=cfg, params=params)
    svc.run(_classifier_stream(cfg, n_requests=4))
    assert isinstance(svc.executor.time_model, LengthBucketTimeModel)
    assert svc.executor.time_model.len_buckets == (16, 64)


@pytest.mark.parametrize("bad", [
    {"mode": "prefill"}, {"block_rows": 0}, {"block_v": True},
    {"len_buckets": []}, {"len_buckets": [4, 4]}, {"len_buckets": [8, 2]},
    {"len_buckets": [1.5]}, {"len_marginal": 2.0}, {"bogus": 1},
])
def test_validate_rejects_bad_kernel_args(bad):
    spec = ServeSpec(executor="device-kernel", executor_args=bad)
    with pytest.raises(ValueError, match="device-kernel"):
        spec.validate()


def test_validate_accepts_kernel_args():
    ServeSpec(executor="device-kernel",
              executor_args={"mode": "decode", "interpret": True,
                             "block_rows": 8, "block_v": 512,
                             "len_buckets": [16, 64],
                             "len_marginal": 0.25}).validate()
    ServeSpec(executor="device-kernel").validate()


def test_build_kernel_executor_decode_mode_factory(tiny_model):
    """The factory seam directly: decode mode builds KernelDecodeStageFns
    over a 1x1 mesh with decode_attn='kernel' and depth-scaled windows."""
    from repro.launch.kernel import KernelDecodeStageFns
    from repro.models import init_params
    from repro.serving.registry import BuildContext
    cfg = _decode_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tm = BatchTimeModel.linear((0.002, 0.003), (1, 2, 4))
    ctx = BuildContext(spec=ServeSpec(pipeline_depth=3),
                       resources={"cfg": cfg, "params": params},
                       time_model=tm, max_batch=4)
    ex = build_kernel_executor({"mode": "decode", "len_buckets": [8, 16]},
                               ctx)
    assert isinstance(ex.stage_fns, KernelDecodeStageFns)
    assert ex.stage_fns.ctx.decode_attn == "kernel"
    assert ex.max_inflight == 2
    assert isinstance(ctx.time_model, LengthBucketTimeModel)
