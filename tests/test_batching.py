"""Tests for the continuous stage-level micro-batching subsystem
(repro.serving.batch): padded batched stage functions match per-sample
outputs, batch formation never violates a member's deadline, admission
control, the closed-loop reissue semantics, and a deterministic
simulate_batched run that strictly beats the unbatched simulator."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EDF, LCF, RTDeepIoT, Task, Workload, make_predictor, simulate
from repro.models import init_params, stage_forward
from repro.serving.batch import (AdmissionController, BatchedPolicy,
                                 BatchTimeModel, StageBatcher,
                                 as_batch_policy, bucket_for, pad_batch,
                                 simulate_batched)
from repro.serving.batch.stage_fns import BatchedStageFns, split_rows

from conftest import make_inputs


def mk_task(deadline, times=(0.004, 0.007, 0.010), executed=0, mandatory=1,
            now=0.0, confs=()):
    t = Task(arrival=now, deadline=deadline, stage_times=tuple(times),
             mandatory=mandatory)
    t.executed = executed
    t.assigned_depth = t.num_stages
    t.confidences = list(confs)
    return t


def oracle_tables(n=600, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


# ---------------------------------------------------------------------------
# BatchTimeModel / buckets
# ---------------------------------------------------------------------------

def test_bucket_rounding_and_wcet_monotone():
    tm = BatchTimeModel.linear((0.004, 0.007, 0.010), (1, 2, 4, 8),
                               marginal=0.2)
    assert tm.bucket_for(1) == 1 and tm.bucket_for(3) == 4
    assert tm.bucket_for(8) == 8
    with pytest.raises(ValueError):
        tm.bucket_for(9)
    for s in range(3):
        ws = [tm.wcet(s, b) for b in (1, 2, 4, 8)]
        assert all(a < b for a, b in zip(ws, ws[1:]))       # bigger = longer
        pi = [tm.per_item(s, b) for b in (1, 2, 4, 8)]
        assert all(a > b for a, b in zip(pi, pi[1:]))       # but cheaper/item
    assert tm.single_times() == (0.004, 0.007, 0.010)


def test_time_model_from_profile_roundtrip():
    mat = np.array([[1.0, 1.5], [2.0, 2.5]])                # (L=2, buckets=2)
    tm = BatchTimeModel.from_profile(mat, (1, 4))
    assert tm.wcet(0, 1) == 1.0 and tm.wcet(0, 3) == 1.5
    assert tm.wcet(1, 4) == 2.5 and tm.num_stages == 2


# ---------------------------------------------------------------------------
# StageBatcher: deadline invariant
# ---------------------------------------------------------------------------

def test_batcher_never_violates_member_deadline():
    """Randomized sweep: every formed batch's (bucket-rounded) WCET meets
    every member's deadline whenever the leader was feasible alone."""
    rng = np.random.default_rng(7)
    tm = BatchTimeModel.linear((0.004, 0.007, 0.010), (1, 2, 4, 8, 16),
                               marginal=0.15)
    batcher = StageBatcher(tm)
    for trial in range(200):
        now = float(rng.uniform(0, 1))
        stage = int(rng.integers(0, 3))
        tasks = [mk_task(now + float(rng.uniform(0.001, 0.08)),
                         executed=int(rng.integers(0, 3)))
                 for _ in range(int(rng.integers(1, 24)))]
        leaders = [t for t in tasks if t.executed == stage]
        if not leaders or not leaders[0].fits_batch(now, tm.wcet(stage, 1)):
            continue
        batch = batcher.form(leaders[0], tasks, now)
        w = tm.wcet(stage, len(batch))
        assert len(batch) <= tm.max_batch
        for m in batch:
            assert m.executed == stage
            assert m.fits_batch(now, w), \
                f"trial {trial}: member deadline violated by batch of " \
                f"{len(batch)} (wcet {w})"
        assert len(set(id(m) for m in batch)) == len(batch)


def test_batcher_growth_respects_bucket_jump():
    """Crossing a bucket boundary re-prices the whole batch: a member that
    fits at bucket 2 but not at bucket 4 blocks growth past 2."""
    st = (0.010,)
    tm = BatchTimeModel.linear(st, (1, 2, 4), marginal=1.0)  # 2x per item
    batcher = StageBatcher(tm)
    now = 0.0
    # bucket WCETs: b=1 -> 10ms, b=2 -> 20ms, b=4 -> 40ms
    leader = mk_task(0.025, times=st)
    tight = mk_task(0.021, times=st)         # fits 20ms, not 40ms
    loose1 = mk_task(0.100, times=st)
    loose2 = mk_task(0.200, times=st)
    batch = batcher.form(leader, [tight, loose1, loose2], now)
    # tight joins at size 2 (20ms); growing to 3 would price at bucket 4
    # (40ms), killing tight AND the leader (25ms) -> growth stops
    assert tight in batch and len(batch) == 2


def test_batcher_dp2_defers_tail_to_dp_multiple():
    """dp-aware formation (sharded seating): a fill that would round up
    to the next bucket trims back to the largest dp multiple when that
    lowers the priced bucket; otherwise the batch is left alone."""
    tm = BatchTimeModel.linear((0.010,), (1, 2, 4, 8), marginal=0.1)
    now = 0.0
    loose = lambda: mk_task(1.0, times=(0.010,))
    # n=5 prices at bucket 8; deferring one task to n=4 prices at bucket 4
    batch = StageBatcher(tm, dp=2).form(loose(), [loose() for _ in range(4)],
                                        now)
    assert len(batch) == 4
    # n=7 -> bucket 8, and n=6 still prices at bucket 8: no gain, no trim
    batch = StageBatcher(tm, dp=2).form(loose(), [loose() for _ in range(6)],
                                        now)
    assert len(batch) == 7
    # n=3 -> bucket 4; n=2 prices at bucket 2: defer one
    batch = StageBatcher(tm, dp=2).form(loose(), [loose() for _ in range(2)],
                                        now)
    assert len(batch) == 2
    # exact bucket hit (n=4, dp=3): no padding rows exist, so no trim
    batch = StageBatcher(tm, dp=3).form(loose(), [loose() for _ in range(3)],
                                        now)
    assert len(batch) == 4
    # the leader is never deferred even when n < dp
    batch = StageBatcher(tm, dp=4).form(loose(), [loose() for _ in range(2)],
                                        now)
    assert len(batch) == 3
    # dp=1 is the identity: the same fill keeps all 5 members
    batch = StageBatcher(tm, dp=1).form(loose(), [loose() for _ in range(4)],
                                        now)
    assert len(batch) == 5


def test_infeasible_leader_runs_solo():
    tm = BatchTimeModel.linear((0.010,), (1, 2), marginal=0.5)
    batcher = StageBatcher(tm)
    leader = mk_task(0.005, times=(0.010,))      # cannot even run alone
    other = mk_task(1.0, times=(0.010,))
    assert batcher.form(leader, [other], 0.0) == [leader]


def test_batched_policy_ranks_by_base_preference():
    """LCF batches lowest-confidence co-runners first when the bucket is
    scarce; EDF picks the earliest deadlines."""
    st = (0.001, 0.001, 0.001)
    tm = BatchTimeModel.linear(st, (1, 2), marginal=0.1)     # room for 2
    now = 0.0
    def tasks():
        a = mk_task(0.5, times=st, executed=1, confs=[0.9])
        b = mk_task(0.4, times=st, executed=1, confs=[0.2])
        c = mk_task(0.3, times=st, executed=1, confs=[0.6])
        return [a, b, c]
    ts = tasks()
    _, batch = as_batch_policy(LCF(), tm).next_batch(ts, now)
    assert [t.confidences[0] for t in batch] == [0.2, 0.6]   # low conf first
    ts = tasks()
    _, batch = as_batch_policy(EDF(), tm).next_batch(ts, now)
    assert [t.deadline for t in batch] == [0.3, 0.4]         # EDF order


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_mandatory_infeasible():
    tm = BatchTimeModel.linear((0.010, 0.010, 0.010), (1, 2), marginal=0.5)
    adm = AdmissionController(tm, mode="reject")
    t = mk_task(0.005)                           # mandatory needs 10ms
    dec = adm.apply([], t, 0.0)
    assert not dec.admitted and dec.reason == "mandatory-infeasible"
    assert t.dropped and adm.rejected == 1


def test_admission_caps_depth_to_feasible():
    tm = BatchTimeModel.linear((0.010, 0.010, 0.010), (1, 2), marginal=0.5)
    adm = AdmissionController(tm, mode="depth_cap")
    t = mk_task(0.025)                           # 2 stages fit, 3 don't
    dec = adm.apply([], t, 0.0)
    assert dec.admitted and t.depth_cap == 2
    # policies clamp against the cap
    EDF().on_arrival([t], t, 0.0)
    assert t.assigned_depth == 2


def test_admission_off_is_noop():
    tm = BatchTimeModel.linear((0.010,), (1,))
    t = mk_task(0.001, times=(0.010,))
    dec = AdmissionController(tm, mode="off").apply([], t, 0.0)
    assert dec.admitted and t.depth_cap is None


# ---------------------------------------------------------------------------
# padded batched stage_forward == per-sample stage_forward
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def anytime_model(rng):
    cfg = get_config("anytime-classifier")
    return cfg, init_params(cfg, rng)


def test_padded_batch_matches_per_sample(anytime_model, rng):
    cfg, params = anytime_model
    n_valid, bucket = 3, 4
    inputs = make_inputs(cfg, jax.random.PRNGKey(3), n_valid, 12)
    singles = split_rows(inputs, n_valid)
    fns = BatchedStageFns(cfg, buckets=(1, bucket))

    # reference: per-sample unbatched stage chain
    ref = []
    for x in singles:
        h = x
        outs = []
        for s in range(cfg.num_stages):
            h, lg, cf = stage_forward(cfg, params, s, h, mode="train")
            outs.append((np.asarray(lg), np.asarray(cf)))
        ref.append(outs)

    # batched: padded to `bucket`, valid rows must match exactly
    hs = singles
    for s in range(cfg.num_stages):
        h_out, logits, conf, mask = fns.run(s, params, hs)
        assert mask.sum() == n_valid and mask.shape == (bucket,)
        logits, conf = np.asarray(logits), np.asarray(conf)
        for i in range(n_valid):
            lg_ref, cf_ref = ref[i][s]
            np.testing.assert_allclose(logits[i], lg_ref[0],
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(conf[i], cf_ref[0],
                                       rtol=1e-4, atol=1e-4)
        hs = split_rows(h_out, n_valid)


def test_pad_batch_shapes_and_mask():
    xs = [{"a": np.full((1, 2), i, np.float32)} for i in range(3)]
    batched, mask = pad_batch(xs, 8)
    assert batched["a"].shape == (8, 2)
    assert list(mask) == [True] * 3 + [False] * 5
    assert np.all(np.asarray(batched["a"][2:]) == 2)         # pad = last row
    assert bucket_for(3, (1, 2, 4, 8)) == 4
    with pytest.raises(ValueError):
        bucket_for(9, (1, 2, 4, 8))


def test_staging_buffers_match_legacy_pad_batch():
    # regression: staged batch formation must produce identical shapes,
    # masks, and values to the legacy re-stacking path
    from repro.serving.batch import StagingBuffers
    st = StagingBuffers()
    for n in (1, 3, 4):
        xs = [{"a": np.full((1, 2), i, np.float32),
               "b": np.full((1, 3, 2), 10 + i, np.int32)} for i in range(n)]
        legacy, lm = pad_batch(xs, 4)
        staged, sm = pad_batch(xs, 4, staging=st)
        assert np.array_equal(lm, sm)
        for k in ("a", "b"):
            assert staged[k].shape == np.asarray(legacy[k]).shape
            assert staged[k].dtype == np.asarray(legacy[k]).dtype
            assert np.array_equal(np.asarray(legacy[k]), staged[k])


def test_staging_buffers_reuse_no_realloc():
    # steady state: same bucket + leaf structure -> the very same numpy
    # buffers and the very same (cached, read-only) mask every dispatch
    from repro.serving.batch import StagingBuffers
    st = StagingBuffers()
    mk = lambda v: {"a": np.full((1, 2), v, np.float32)}
    b1, m1 = st.stage([mk(0), mk(1)], 4)
    b2, m2 = st.stage([mk(5), mk(6)], 4)
    assert b1["a"] is b2["a"] and m1 is m2
    assert not m1.flags.writeable
    assert np.all(b2["a"][:2] == [[5, 5], [6, 6]])
    assert np.all(b2["a"][2:] == 6)                          # pad = last row
    # a different valid count re-pads in place with a fresh cached mask
    b3, m3 = st.stage([mk(9)], 4)
    assert b3["a"] is b1["a"] and m3 is not m1
    assert np.all(b3["a"] == 9)


def test_batched_stage_fns_staging_results_stable(anytime_model):
    # BatchedStageFns.run with its built-in staging gives bitwise-identical
    # outputs dispatch after dispatch (buffer reuse must not leak rows)
    cfg, params = anytime_model
    inputs = make_inputs(cfg, jax.random.PRNGKey(7), 2, 12)
    singles = split_rows(inputs, 2)
    fns = BatchedStageFns(cfg, buckets=(1, 4))
    outs = []
    for _ in range(2):
        h, lg, cf, mask = fns.run(0, params, singles)
        outs.append((np.asarray(lg), np.asarray(cf), mask.copy()))
    assert np.array_equal(outs[0][0], outs[1][0])
    assert np.array_equal(outs[0][1], outs[1][1])
    assert np.array_equal(outs[0][2], outs[1][2])


# ---------------------------------------------------------------------------
# closed-loop semantics (satellite: reissue at completion, not deadline)
# ---------------------------------------------------------------------------

def test_closed_loop_reissues_at_completion():
    """One client, huge deadlines: request i+1 must be issued right when
    request i completes, not when its deadline would have expired."""
    conf, correct = oracle_tables()
    wl = Workload(n_clients=1, d_lo=1.0, d_hi=1.0, n_requests=5, seed=3)
    st = (0.004, 0.007, 0.010)
    res = simulate(EDF(), wl, st, conf, correct)
    assert res.miss_rate == 0.0
    arrivals = sorted(f["arrival"] for f in res.per_request)
    gaps = np.diff(arrivals)
    # EDF runs every stage: turnaround = 21ms << the 1s deadline
    assert np.all(gaps < 0.1), f"client waited for its deadline: {gaps}"
    assert np.allclose(gaps, sum(st), atol=1e-6)


# ---------------------------------------------------------------------------
# simulate_batched: batching strictly beats unbatched serving
# ---------------------------------------------------------------------------

def test_batched_sim_beats_unbatched_throughput():
    """Deterministic overload run: the batched path sustains >= 3x the
    goodput of the unbatched path at no-worse miss rate and accuracy."""
    conf, correct = oracle_tables()
    st = (0.004, 0.007, 0.010)
    tm = BatchTimeModel.linear(st, (1, 2, 4, 8, 16), marginal=0.15)
    wl = Workload(n_clients=64, d_lo=0.01, d_hi=0.3, n_requests=500, seed=0)

    def policy():
        return RTDeepIoT(make_predictor("exp", prior_curve=conf.mean(0)))

    res_u = simulate(policy(), wl, st, conf, correct)
    res_b = simulate_batched(policy(), wl, tm, conf, correct)
    assert res_b.throughput >= 3.0 * res_u.throughput, \
        f"batched {res_b.throughput:.1f} req/s vs unbatched " \
        f"{res_u.throughput:.1f} req/s"
    assert res_b.miss_rate <= res_u.miss_rate
    assert res_b.accuracy >= res_u.accuracy - 0.01


def test_batched_sim_respects_wrapped_policy_depth():
    """Batched EDF still serves every request to full depth when load is
    light — batching must not change *what* is computed, only how."""
    conf, correct = oracle_tables()
    st = (0.001, 0.001, 0.001)
    tm = BatchTimeModel.linear(st, (1, 2, 4), marginal=0.1)
    wl = Workload(n_clients=2, d_lo=0.5, d_hi=0.5, n_requests=20, seed=1)
    res = simulate_batched(EDF(), wl, tm, conf, correct)
    assert res.miss_rate == 0.0
    assert res.mean_depth == pytest.approx(3.0)


def test_batched_sim_admission_reduces_wasted_work():
    """Under overload, rejecting infeasible arrivals must not hurt goodput
    and every rejected request is accounted as a miss."""
    conf, correct = oracle_tables()
    st = (0.004, 0.007, 0.010)
    tm = BatchTimeModel.linear(st, (1, 2, 4, 8, 16), marginal=0.15)
    wl = Workload(n_clients=64, d_lo=0.01, d_hi=0.3, n_requests=400, seed=0)
    adm = AdmissionController(tm, mode="reject", headroom=1.0)
    res = simulate_batched(EDF(), wl, tm, conf, correct, admission=adm)
    n_rej = sum(1 for f in res.per_request if f.get("rejected"))
    assert n_rej == adm.rejected
    for f in res.per_request:
        if f.get("rejected"):
            assert f["missed"] and f["depth"] == 0
    assert res.n_requests == wl.n_requests


def test_wrapped_policy_telemetry_passthrough():
    conf, _ = oracle_tables()
    tm = BatchTimeModel.linear((0.004, 0.007, 0.010), (1, 2, 4))
    base = RTDeepIoT(make_predictor("exp", prior_curve=conf.mean(0)))
    pol = as_batch_policy(base, tm)
    assert isinstance(pol, BatchedPolicy)
    assert pol.name == f"batched-{base.name}"
    assert pol.sched_time == base.sched_time
    assert as_batch_policy(pol, tm) is pol                   # idempotent
