"""Roofline machinery tests: HLO collective parser, probe fit math, and the
table row computation."""
import pytest

from repro.roofline.collectives import collective_bytes_from_hlo
from repro.roofline.probes import (_eval_linear, _eval_quad, _fit_linear,
                                   _fit_quad, METRICS)

HLO = """
HloModule jit_f

%region_1.0 (a: f32[], b: f32[]) -> f32[] {
  ROOT %add = f32[] add(%a, %b)
}

%while_body (arg: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ag = f32[16,128]{1,0} all-gather(%x), channel_id=3, dimensions={1}
  ROOT %t = (s32[], f32[16,128]) tuple(%i, %ag)
}

ENTRY %main (p0: f32[8,128], p1: bf16[4,256]) -> f32[8,128] {
  %ar = f32[8,128]{1,0} all-reduce(%p0), channel_id=1, to_apply=%region_1.0
  %a2a = bf16[4,256]{1,0} all-to-all(%p1), channel_id=2
  %rs = f32[2,128]{1,0} reduce-scatter(%ar), channel_id=4
  %cp = f32[8,128]{1,0} collective-permute(%rs), channel_id=5
  ROOT %out = f32[8,128]{1,0} add(%ar, %cp)
}
"""


def test_collective_parser_counts_and_bytes():
    res = collective_bytes_from_hlo(HLO)
    assert res["count"] == 5
    by = res["by_op"]
    assert by["all-reduce"] == pytest.approx(2 * 8 * 128 * 4)   # 2x operand
    assert by["all-to-all"] == pytest.approx(4 * 256 * 2)       # bf16
    assert by["reduce-scatter"] == pytest.approx(2 * 128 * 4)
    assert by["collective-permute"] == pytest.approx(8 * 128 * 4)
    assert by["all-gather"] == pytest.approx(16 * 128 * 4)      # result bytes
    assert res["total"] == pytest.approx(sum(by.values()))


def test_collective_parser_attributes_computations():
    res = collective_bytes_from_hlo(HLO)
    comps = res["by_computation"]
    # the while-body all-gather is attributed separately from ENTRY
    assert any("while_body" in k for k in comps)
    assert sum(v for k, v in comps.items()) == pytest.approx(res["total"])


def test_fit_quad_exact_recovery():
    # cost = 3*S + 0.5*S^2 for every metric
    f = lambda S: {m: 3 * S + 0.5 * S * S for m in METRICS}
    fit = _fit_quad(f(128), 128, f(256), 256)
    got = _eval_quad(fit, 4096)
    for m in METRICS:
        assert got[m] == pytest.approx(3 * 4096 + 0.5 * 4096 ** 2, rel=1e-9)


def test_fit_linear_exact_recovery():
    f = lambda S: {m: 7.0 + 2.5 * S for m in METRICS}
    fit = _fit_linear(f(64), 64, f(128), 128)
    got = _eval_linear(fit, 1024)
    for m in METRICS:
        assert got[m] == pytest.approx(7.0 + 2.5 * 1024, rel=1e-9)


def test_fit_never_negative():
    # noisy points that would extrapolate negative are clamped at 0
    lo = {m: 100.0 for m in METRICS}
    hi = {m: 10.0 for m in METRICS}          # decreasing -> negative slope
    fit = _fit_linear(lo, 64, hi, 128)
    got = _eval_linear(fit, 4096)
    for m in METRICS:
        assert got[m] >= 0.0


def test_roofline_row_terms():
    from benchmarks.bench_roofline import roofline_row
    rec = {
        "arch": "qwen3-4b", "shape": "train_4k", "mesh": "16x16",
        "kind": "train", "moe_impl": "alltoall", "variant": "final",
        "probe": {"totals": {"flops": 197e12, "bytes": 819e9,
                             "coll": 50e9}},
        "memory": {"argument_bytes": 8e9, "temp_bytes": 4e9,
                   "output_bytes": 2e9},
    }
    row = roofline_row(rec)
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert row["hbm_frac"] == pytest.approx(14 / 16)
    assert row["fits"]
    assert row["useful_ratio"] > 0
