"""MoE unit + property tests: routing invariants, capacity semantics,
dispatch-table correctness, load-balance loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


def _cfg(E=4, k=2, cf=1.25, d_ff=32, shared=0):
    base = get_config("qwen3-4b").reduced()
    return dataclasses.replace(
        base, moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=d_ff,
                            capacity_factor=cf, num_shared_experts=shared))


def test_route_gates_normalized():
    cfg = _cfg()
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 4))
    gates, idx, aux = moe_mod._route(cfg, logits)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert bool((idx >= 0).all()) and bool((idx < 4).all())
    assert float(aux) >= 0.95                # ~1 when roughly balanced


def test_aux_loss_minimal_when_balanced():
    cfg = _cfg(E=4, k=1)
    # perfectly uniform router -> aux == E * sum_e (1/E * 1/E) * E... == 1
    logits = jnp.zeros((64, 4))
    _, _, aux_uniform = moe_mod._route(cfg, logits)
    # maximally imbalanced: all tokens to expert 0
    logits_bad = jnp.full((64, 4), -10.0).at[:, 0].set(10.0)
    _, _, aux_bad = moe_mod._route(cfg, logits_bad)
    assert float(aux_bad) > float(aux_uniform) * 1.5


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(4, 64), st.integers(2, 8),
       st.integers(1, 3))
def test_dispatch_tables_property(seed, T, E, k):
    """Every expert slot holds a distinct (token, expert) assignment; no
    expert exceeds capacity; kept assignments are exactly the lowest-rank
    ones per expert."""
    cfg = _cfg(E=E, k=min(k, E))
    k = cfg.moe.top_k
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, E, size=(T, k)))
    cap = moe_mod._capacity(cfg, T)
    dispatch, assign = moe_mod._dispatch_tables(cfg, idx, T, cap)
    dispatch = np.asarray(dispatch)
    assign = np.asarray(assign)
    flat = np.asarray(idx).reshape(-1)
    for e in range(E):
        slots = dispatch[e]
        used = slots[slots < T]
        # every filled slot's token really routed to e
        for c, tok in enumerate(slots):
            if tok < T:
                a = assign[e, c]
                assert a >= 0
                assert flat[a] == e
                assert a // k == tok
        assert len(used) <= cap
        # count of kept == min(total routed to e, cap)
        assert len(used) == min((flat == e).sum(), cap)


def test_capacity_bounds():
    cfg = _cfg(E=256, k=8, cf=1.25)
    # tiny token count: no 4x256 padding explosion (§Perf iteration 1b)
    assert moe_mod._capacity(cfg, 8) <= 8 * 8
    assert moe_mod._capacity(cfg, 8) >= 1
    # large token count: ~ T*k*cf/E
    c = moe_mod._capacity(cfg, 65536)
    assert abs(c - 65536 * 8 * 1.25 / 256) <= 4


def test_moe_gather_zero_for_dropped_tokens():
    """With capacity 1 and many tokens on one expert, dropped tokens receive
    only the shared-expert (here: zero) contribution."""
    cfg = _cfg(E=2, k=1, cf=0.01)
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    T, d = 16, cfg.d_model
    h = jnp.ones((T, d))
    # force all tokens to expert 0
    params = dict(params, router=jnp.zeros((d, 2)).at[:, 0].set(1.0))
    y, aux = moe_mod.moe_gather(cfg, params, h, None)
    cap = moe_mod._capacity(cfg, T)
    nz = np.asarray(jnp.abs(y).sum(-1) > 1e-6)
    assert nz.sum() == cap


def test_moe_deterministic():
    cfg = _cfg()
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (24, cfg.d_model))
    y1, a1 = moe_mod.moe_gather(cfg, params, h, None)
    y2, a2 = moe_mod.moe_gather(cfg, params, h, None)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_shared_expert_always_contributes():
    cfg = _cfg(shared=1, cf=0.01)   # near-zero routed capacity
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_mod.apply_moe(cfg, params, x)
    # residual + shared expert => output differs from input everywhere
    assert bool((jnp.abs(y - x).sum(-1) > 1e-6).all())
