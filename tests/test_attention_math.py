"""Attention math property tests: chunked == dense, local == windowed dense,
flash-decode == dense decode, MLA absorbed decode == expanded reference,
chunked mLSTM == sequential recurrence, chunked Mamba == naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as A


def _qkv(seed, B, S, KV, G, hd):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    return q, k, v


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([8, 16, 32]),
       st.sampled_from([1, 2]), st.sampled_from([1, 3]),
       st.booleans())
def test_chunked_equals_dense(seed, S, KV, G, causal):
    q, k, v = _qkv(seed, 2, S, KV, G, 16)
    pos = jnp.arange(S)
    dense = A.attend_dense(q, k, v, causal=causal, q_pos=pos, k_pos=pos,
                           window=None, softmax_scale=0.25)
    chunk = A.attend_chunked(q, k, v, q_pos=pos, k_pos=pos, window=None,
                             softmax_scale=0.25, q_chunk=8, causal=causal)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([4, 8]))
def test_local_equals_windowed_dense(seed, w):
    S = 4 * w
    q, k, v = _qkv(seed, 2, S, 2, 2, 16)
    pos = jnp.arange(S)
    dense = A.attend_dense(q, k, v, causal=True, q_pos=pos, k_pos=pos,
                           window=w, softmax_scale=0.25)
    local = A.attend_local(q, k, v, q_pos=pos, k_pos=pos, window=w,
                           softmax_scale=0.25)
    np.testing.assert_allclose(np.asarray(local), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_mlstm_chunked_equals_sequential():
    """The chunk-parallel mLSTM must reproduce the per-step recurrence."""
    from repro.models import xlstm as X
    B, S, H, dh = 2, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
             "m": jnp.full((B, H), -1e30)}
    # sequential reference
    hs_ref = []
    st_ = state
    for t in range(S):
        h, st_ = X.mlstm_step(q[:, t], k[:, t], v[:, t], i_pre[:, t],
                              f_pre[:, t], st_)
        hs_ref.append(h)
    ref = jnp.stack(hs_ref, 1)
    # chunked (chunk 8)
    old = X.MLSTM_CHUNK
    X.MLSTM_CHUNK = 8
    try:
        out, final = X.mlstm_chunked(q, k, v, i_pre, f_pre, state)
    finally:
        X.MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final["C"]), np.asarray(st_["C"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final["m"]), np.asarray(st_["m"]),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_naive():
    """Chunked selective scan == naive per-step linear recurrence."""
    from repro.models import ssm as M
    B, S, di, ds = 2, 24, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    Bm = jax.random.normal(ks[2], (B, S, ds))
    C = jax.random.normal(ks[3], (B, S, ds))
    A_ = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (di, ds)))
    state0 = jnp.zeros((B, di, ds))
    old = M.CHUNK
    M.CHUNK = 8
    try:
        cfgd = None
        y, final = M.mamba_scan_full(cfgd, x, dt, Bm, C, A_, state0)
    finally:
        M.CHUNK = old
    # naive reference
    s = state0
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t][..., None] * A_)
        bx = (dt[:, t] * x[:, t])[..., None] * Bm[:, t][:, None, :]
        s = a * s + bx
        ys.append(jnp.einsum("bds,bs->bd", s, C[:, t]))
    ref = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(s),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_equals_full():
    """covered end-to-end in test_models_smoke; here: last-token logits of
    full fwd == decode after prefix replay for the MLA reduced config."""
    import dataclasses

    from conftest import make_inputs
    from repro.configs import get_config
    from repro.models import (decode_step, forward, init_decode_cache,
                              init_params)
    cfg = dataclasses.replace(get_config("deepseek-v3-671b").reduced(),
                              moe=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    inputs = make_inputs(cfg, jax.random.PRNGKey(1), B, S)
    ref = forward(cfg, params, inputs, mode="train").logits[-1][:, -1]
    cache = init_decode_cache(cfg, B, S)
    for t in range(S):
        ex, cache = decode_step(cfg, params, cache, inputs["tokens"][:, t],
                                jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(ex.logits[-1]), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_rope_rotation_invariance():
    """RoPE: score of (q at pos i, k at pos j) depends only on i - j."""
    from repro.models.common import apply_rope
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def score(i, j):
        qr = apply_rope(q, jnp.array([[i]]), 1e4)
        kr = apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert score(5, 3) == pytest.approx(score(12, 10), abs=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), abs=1e-4)
