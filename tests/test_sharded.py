"""Tests for the device-sharded executor (repro.launch.sharded).

The contract under test, alongside the tests/test_runtime.py goldens:

* **Mesh fallback** — ``make_serving_mesh`` degenerates to 1x1 when the
  host lacks ``dp * tp`` devices (``require=True`` raises instead), so one
  ServeSpec runs everywhere and single-device CI exercises the full
  sharded code path.
* **Parity** — on the 1x1 fallback mesh, ``executor="device-sharded"``
  must reproduce ``device-batched`` results **bit-for-bit** under the
  virtual clock, for both a stream source and a traffic scenario.
* **Pricing** — ``sharded_time_model`` scales buckets to dp-divisible
  global sizes (identity at dp=1, so golden parity is untouched) and adds
  the collective term only when dp > 1.
* **Validation** — ``ServeSpec.validate()`` rejects malformed dp/tp
  factors and mesh axis lists at spec time.
* **Hidden-state cache** — per-request state persists across stage
  dispatches and is fully evicted on retire.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

import repro.launch.serve  # noqa: F401 — registers device-sharded
from repro.launch.mesh import make_serving_mesh
from repro.launch.sharded import dp_buckets, sharded_time_model
from repro.serving import (BatchTimeModel, ServeSpec, Service,
                           closed_loop_stream)
from repro.serving.traffic import scenario_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STAGE_TIMES = (0.002, 0.003, 0.004)


# ---------------------------------------------------------------------------
# mesh + pricing units
# ---------------------------------------------------------------------------

def test_make_serving_mesh_falls_back_to_1x1():
    n = len(jax.devices())
    mesh = make_serving_mesh(n + 1, 2)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(n + 1, 2, require=True)
    with pytest.raises(ValueError, match=">= 1"):
        make_serving_mesh(0, 1)


def test_make_serving_mesh_axes():
    mesh = make_serving_mesh(1, 1, axes=("rows", "cols"))
    assert mesh.axis_names == ("rows", "cols")


def test_dp_buckets():
    assert dp_buckets((1, 2, 4), 1) == (1, 2, 4)
    assert dp_buckets((1, 2, 4), 2) == (2, 4, 8)
    assert dp_buckets((4, 2, 1), 2) == (2, 4, 8)   # sorts
    with pytest.raises(ValueError):
        dp_buckets((1, 2), 0)


def test_sharded_time_model_identity_at_dp1():
    tm = BatchTimeModel.linear(STAGE_TIMES, (1, 2, 4), marginal=0.15)
    assert sharded_time_model(tm, 1, collective=0.123) is tm


def test_sharded_time_model_prices_per_shard_bucket():
    tm = BatchTimeModel.linear(STAGE_TIMES, (1, 2, 4), marginal=0.15)
    c = 5e-4
    stm = sharded_time_model(tm, 4, collective=c)
    assert stm.buckets == (4, 8, 16)
    # a global batch of 4 puts 1 row per device: single-row WCET + sync
    for s in range(len(STAGE_TIMES)):
        assert stm.wcet(s, 4) == pytest.approx(tm.wcet(s, 1) + c)
        # 5 rows pad to global bucket 8 = per-shard bucket 2
        assert stm.wcet(s, 5) == pytest.approx(tm.wcet(s, 2) + c)
    assert stm.single_times() == tuple(t + c for t in tm.single_times())


# ---------------------------------------------------------------------------
# ServeSpec.validate()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    {"dp": 0}, {"dp": -2}, {"dp": 2.5}, {"dp": True}, {"tp": 0},
    {"tp": "2"}, {"mesh": ["data"]}, {"mesh": ["x", "x"]},
    {"mesh": "data,model"}, {"collective": -1.0}, {"bogus": 1},
])
def test_validate_rejects_bad_sharded_args(bad):
    spec = ServeSpec(executor="device-sharded", executor_args=bad)
    with pytest.raises(ValueError, match="device-sharded"):
        spec.validate()


def test_validate_accepts_sharded_args():
    ServeSpec(executor="device-sharded",
              executor_args={"dp": 4, "tp": 2, "mesh": ["data", "model"],
                             "require": False, "collective": 2e-4}).validate()
    ServeSpec(executor="device-sharded").validate()   # all defaults


# ---------------------------------------------------------------------------
# 1x1-mesh parity against device-batched (the CI acceptance gate)
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("anytime-classifier")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _stream_spec(executor, executor_args):
    return ServeSpec(
        policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        executor=executor, executor_args=executor_args,
        clock="virtual", source="stream",
        batching={"buckets": [1, 2, 4], "stage_times": list(STAGE_TIMES),
                  "marginal": 0.25})


def _response_key(responses):
    return [(r.sample, r.prediction, r.confidence, r.depth, r.missed,
             r.latency, r.deadline) for r in responses]


@pytest.fixture(scope="module")
def tiny_model():
    return _tiny_model()


def test_sharded_equals_batched_bitwise_stream(tiny_model):
    cfg, params = tiny_model
    from repro.training import DifficultyDataset
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(30, seed=9)
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=4,
                                d_lo=0.2, d_hi=0.5, n_requests=12, seed=1)
    runs = {}
    for ex, ea in (("device-batched", {}),
                   ("device-sharded", {"dp": 8, "tp": 8})):
        svc = Service.from_spec(_stream_spec(ex, ea), cfg=cfg, params=params)
        svc.run(list(stream))
        runs[ex] = svc
    sx = runs["device-sharded"].executor
    if len(jax.devices()) == 1:          # the CI path: fallback engaged
        assert sx.fallback and sx.dp == 1 and sx.tp == 1
        assert sx.stage_fns.buckets == (1, 2, 4)
    assert _response_key(runs["device-sharded"].responses) \
        == _response_key(runs["device-batched"].responses)


def test_sharded_traffic_scenario_bitwise_parity(tiny_model):
    """The batched traffic scenario end-to-end through the registry:
    identical per-request records on the 1x1 mesh."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    pool = rng.normal(size=(32, 1, 16, 32)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab_size, size=32)
    base = scenario_spec(
        "steady", policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        stage_times=STAGE_TIMES, n_requests=16, seed=0)
    base.batching = {"buckets": [1, 2, 4], "stage_times": list(STAGE_TIMES),
                     "marginal": 0.25}
    recs = {}
    for ex, ea in (("device-batched", {}), ("device-sharded", {"dp": 2})):
        spec = dataclasses.replace(base, executor=ex, executor_args=ea)
        res = Service.from_spec(
            spec, cfg=cfg, params=params, n_samples=len(pool), labels=labels,
            traffic_inputs=lambda s: {"features": pool[s]}).run()
        assert res.n_requests == 16
        recs[ex] = [(r["sample"], r["slo"], r["prediction"], r["conf"],
                     r["depth"], r["missed"], r["latency"])
                    for r in res.per_request]
    assert recs["device-sharded"] == recs["device-batched"]


def test_sharded_rejects_mismatched_stage_fns_resource(tiny_model):
    """A caller-supplied stage_fns whose bucket set does not match the
    dp-scaled global buckets must fail at build time, not at the first
    over-bucket dispatch."""
    from repro.serving import BatchedStageFns
    cfg, params = tiny_model
    svc = Service.from_spec(_stream_spec("device-sharded", {}), cfg=cfg,
                            params=params,
                            stage_fns=BatchedStageFns(cfg, (1, 2)))
    with pytest.raises(ValueError, match="bucket set"):
        svc.run([])


def test_sharded_hidden_state_cache_evicted_on_retire(tiny_model):
    cfg, params = tiny_model
    from repro.training import DifficultyDataset
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(20, seed=5)
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=3,
                                d_lo=0.2, d_hi=0.4, n_requests=9, seed=2)
    svc = Service.from_spec(_stream_spec("device-sharded", {}), cfg=cfg,
                            params=params)
    svc.run(list(stream))
    ex = svc.executor
    # every request's state was admitted, persisted while live, and
    # evicted exactly once at retire — nothing leaks past drain
    assert ex.cache_stats() == dict(live=0, peak=ex.peak_cached, evictions=9)
    assert ex.peak_cached >= 1
    assert ex.states == {}


# ---------------------------------------------------------------------------
# a real (non-degenerate) mesh, forced host devices — subprocess like
# tests/test_distributed.py
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_on_forced_two_device_mesh():
    """dp=2 on two forced host devices: the mesh is NOT a fallback, global
    buckets double, and results still match device-batched (row sharding
    keeps per-row math on a single device, so even bitwise holds)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, numpy as np
        import repro.launch.serve
        from repro.serving import ServeSpec, Service, closed_loop_stream
        from repro.configs import get_config
        from repro.models import init_params
        from repro.training import DifficultyDataset

        cfg = get_config("anytime-classifier")
        params = init_params(cfg, jax.random.PRNGKey(0))
        ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
        test = ds.sample(20, seed=9)
        stream = closed_loop_stream(test["inputs"], test["labels"],
                                    n_clients=4, d_lo=0.2, d_hi=0.5,
                                    n_requests=10, seed=1)
        runs = {}
        for ex, ea in (("device-batched", {}),
                       ("device-sharded", {"dp": 2, "tp": 1})):
            spec = ServeSpec(
                policy="rtdeepiot",
                policy_args={"predictor": "exp",
                             "prior_curve": [0.5, 0.7, 0.85]},
                executor=ex, executor_args=ea, clock="virtual",
                source="stream",
                batching={"buckets": [1, 2, 4],
                          "stage_times": [0.002, 0.003, 0.004],
                          "marginal": 0.25})
            svc = Service.from_spec(spec, cfg=cfg, params=params)
            svc.run(list(stream))
            runs[ex] = svc
        sx = runs["device-sharded"].executor
        assert not sx.fallback and sx.dp == 2 and sx.tp == 1
        assert sx.stage_fns.buckets == (2, 4, 8)
        assert sx.time_model.buckets == (2, 4, 8)
        key = lambda svc: [(r.sample, r.prediction, r.confidence, r.depth,
                            r.missed) for r in svc.responses]
        assert key(runs["device-sharded"]) == key(runs["device-batched"])
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    assert "OK" in r.stdout
