"""Tests for the adaptive-control subsystem (repro.serving.adaptive).

* offset extraction from every arrival record the repo produces (arrays,
  per_request rows, trace JSONL, journal directories)
* workload fits: each ArrivalProcess kind's parameters are recovered
  within tolerance from its own traces; ``fit_report`` identifies the
  generating kind for all four kinds (property tests ride hypothesis)
* OnlineCurveEstimator: converges to the oracle mean table, stays
  monotone-in-depth under arbitrary observations, per-key isolation,
  decayed forgetting, JSON round trip
* AdaptivePredictor honors the UtilityPredictor contract (measured
  prefix, monotone learned suffix); ``rtdeepiot-adaptive`` runs through
  the Service facade and warms a shared estimator resource
* PredictiveAdmissionController: forecast-capped / forecast-overload
  decisions carry the numbers behind the rule into the obs audit log;
  spec-level ``admission["forecast"]`` wiring and validation
* TrafficDriver: wall-clock pacing into Service.submit() [wallclock],
  materialization determinism vs the virtual-clock traffic source
"""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.task import Task
from repro.serving import ServeSpec, Service, record_trace
from repro.serving.adaptive import (AdaptivePredictor, AdaptiveRTDeepIoT,
                                    OnlineCurveEstimator,
                                    PredictiveAdmissionController,
                                    extract_offsets, fit_arrival_process,
                                    fit_diurnal, fit_flash_crowd, fit_mmpp,
                                    fit_poisson, fit_report,
                                    predictive_admission)
from repro.serving.adaptive.driver import TrafficDriver
from repro.serving.batch import BatchTimeModel
from repro.serving.registry import available
from repro.serving.traffic import load_trace, make_arrival_process
from repro.serving.traffic.scenarios import scenario_spec

STAGE_TIMES = (0.004, 0.007, 0.010)

ARRIVAL_CONFIGS = {
    "poisson": dict(rate=80.0),
    "mmpp": dict(rate_on=300.0, rate_off=40.0, mean_on=0.4, mean_off=1.2),
    "diurnal": dict(base_rate=40.0, peak_rate=200.0, period=4.0),
    "flash-crowd": dict(base_rate=60.0, spike_rate=400.0, spike_at=1.0,
                        spike_len=1.0),
}


def oracle_tables(n=200, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def sample_offsets(kind, seed=0, n=2000):
    p = make_arrival_process(kind, **ARRIVAL_CONFIGS[kind])
    return p.sample(np.random.default_rng(seed), n=n)


def mk_task(deadline, times=STAGE_TIMES, mandatory=1, now=0.0, model=None):
    t = Task(arrival=now, deadline=deadline, stage_times=tuple(times),
             mandatory=mandatory, model=model)
    t.assigned_depth = t.num_stages
    return t


# ---------------------------------------------------------------------------
# extract_offsets: one reader for every arrival record
# ---------------------------------------------------------------------------

def test_extract_offsets_sorts_plain_arrays():
    got = extract_offsets([0.3, 0.1, 0.2])
    assert got.tolist() == [0.1, 0.2, 0.3]


def test_extract_offsets_per_request_rows_prefer_offset():
    rows = [{"offset": 0.2, "arrival": 9.0}, {"arrival": 0.1}]
    assert extract_offsets(rows).tolist() == [0.1, 0.2]


def test_extract_offsets_from_recorded_trace(tmp_path):
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", stage_times=STAGE_TIMES, n_requests=40,
                         seed=1)
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    path = str(tmp_path / "trace.jsonl")
    record_trace(res, path, source="traffic", spec=spec)
    offs = extract_offsets(path)
    want = np.sort([r["offset"] for r in res.per_request])
    assert np.allclose(offs, want)
    # the in-memory event list reads the same
    _, events = load_trace(path)
    assert np.allclose(extract_offsets(events), want)


def test_extract_offsets_journal_dir_counts_submits_only(tmp_path):
    from repro.serving.plane import Journal
    d = str(tmp_path / "wal")
    with Journal(d) as j:
        for i in range(10):
            j.append("SUBMIT", offset=0.01 * i, sample=i,
                     request_id=f"r{i}", rel_deadline=0.2)
            j.append("RETIRE", offset=0.01 * i + 0.005, sample=i,
                     request_id=f"r{i}")
    offs = extract_offsets(d)
    assert len(offs) == 10                      # RETIREs are not arrivals
    assert np.allclose(offs, 0.01 * np.arange(10))


def test_fit_needs_enough_arrivals(tmp_path):
    with pytest.raises(ValueError, match="need >="):
        fit_poisson([0.0, 1.0])
    with pytest.raises(ValueError, match="span zero"):
        fit_poisson([1.0] * 20)
    with pytest.raises(ValueError, match="no wal-"):
        extract_offsets(tmp_path)               # a dir with no segments


# ---------------------------------------------------------------------------
# workload fits: parameter recovery per kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fit_poisson_recovers_rate(seed):
    f = fit_poisson(sample_offsets("poisson", seed, n=1500))
    assert f["kind"] == "poisson"
    assert f["rate"] == pytest.approx(80.0, rel=0.10)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fit_mmpp_recovers_state_rates_and_dwells(seed):
    f = fit_mmpp(sample_offsets("mmpp", seed, n=2500))
    cfg = ARRIVAL_CONFIGS["mmpp"]
    assert f["rate_on"] == pytest.approx(cfg["rate_on"], rel=0.15)
    assert f["rate_off"] == pytest.approx(cfg["rate_off"], rel=0.30)
    # dwell means are the hard part (few on/off cycles per trace): accept
    # a factor-2.5 band, but the on/off ordering must be unambiguous
    assert cfg["mean_on"] / 2.5 < f["mean_on"] < cfg["mean_on"] * 2.5
    assert cfg["mean_off"] / 2.5 < f["mean_off"] < cfg["mean_off"] * 2.5
    assert f["rate_on"] > 2 * f["rate_off"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fit_diurnal_recovers_period_and_peak(seed):
    f = fit_diurnal(sample_offsets("diurnal", seed, n=2500))
    cfg = ARRIVAL_CONFIGS["diurnal"]
    assert f["period"] == pytest.approx(cfg["period"], rel=0.10)
    assert f["peak_rate"] == pytest.approx(cfg["peak_rate"], rel=0.15)
    assert f["base_rate"] < f["peak_rate"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fit_flash_crowd_recovers_spike(seed):
    f = fit_flash_crowd(sample_offsets("flash-crowd", seed, n=2000))
    cfg = ARRIVAL_CONFIGS["flash-crowd"]
    assert f["base_rate"] == pytest.approx(cfg["base_rate"], rel=0.10)
    assert f["spike_rate"] == pytest.approx(cfg["spike_rate"], rel=0.20)
    assert abs(f["spike_at"] - cfg["spike_at"]) < 0.15
    assert f["spike_len"] == pytest.approx(cfg["spike_len"], rel=0.20)


def test_fit_flash_crowd_without_spike_degenerates_to_base():
    f = fit_flash_crowd(np.linspace(0.0, 10.0, 400))   # perfectly flat
    assert f["spike_len"] == 0.0
    assert f["spike_rate"] == f["base_rate"]


@pytest.mark.parametrize("kind", sorted(ARRIVAL_CONFIGS))
@pytest.mark.parametrize("seed", [0, 1])
def test_fit_report_identifies_the_generating_kind(kind, seed):
    rep = fit_report(sample_offsets(kind, seed))
    assert rep["best"] == kind, rep["scores"]
    assert set(rep["fits"]) == set(ARRIVAL_CONFIGS)
    assert set(rep["scores"]) == set(ARRIVAL_CONFIGS)
    assert rep["n_arrivals"] == 2000
    # every fitted dict round-trips through the generator factory
    for f in rep["fits"].values():
        make_arrival_process(**f)


def test_fit_arrival_process_returns_best_process():
    p = fit_arrival_process(sample_offsets("diurnal", 0))
    assert p.to_dict()["kind"] == "diurnal"
    assert p.mean_rate == pytest.approx(120.0, rel=0.15)   # (40+200)/2


@given(rate=st.floats(min_value=20.0, max_value=300.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fit_poisson_recovery_property(rate, seed):
    """Property: any homogeneous rate is recovered within 15% from 1200
    arrivals (MLE conditioning on the first arrival)."""
    p = make_arrival_process("poisson", rate=rate)
    offs = p.sample(np.random.default_rng(seed), n=1200)
    assert fit_poisson(offs)["rate"] == pytest.approx(rate, rel=0.15)


@given(period=st.floats(min_value=2.0, max_value=6.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fit_diurnal_period_recovery_property(period, seed):
    """Property: the Rayleigh scan recovers any period that fits >= ~2
    observed cycles, within 15%."""
    p = make_arrival_process("diurnal", base_rate=40.0, peak_rate=200.0,
                             period=period)
    offs = p.sample(np.random.default_rng(seed), n=2500)
    if (offs[-1] - offs[0]) / period < 2.0:    # under-observed cycle
        return
    assert fit_diurnal(offs)["period"] == pytest.approx(period, rel=0.15)


# ---------------------------------------------------------------------------
# OnlineCurveEstimator
# ---------------------------------------------------------------------------

def test_estimator_converges_to_oracle_mean():
    oracle, _ = oracle_tables(n=600)
    est = OnlineCurveEstimator(num_stages=3, prior_weight=0.0)
    for row in oracle:
        est.observe_exits(row)
    learned = est.curve()
    assert np.abs(learned - oracle.mean(0)).max() < 0.05
    assert np.all(np.diff(learned) >= 0)
    assert est.n_observed == oracle.size


def test_estimator_unseen_key_falls_back_to_prior():
    prior = [0.4, 0.6, 0.8]
    est = OnlineCurveEstimator(num_stages=3, prior=prior)
    assert est.curve("never-seen").tolist() == prior
    assert est.weight("never-seen").tolist() == [0.0] * 3


def test_estimator_keys_are_isolated():
    est = OnlineCurveEstimator(num_stages=2, prior=[0.5, 0.5],
                               prior_weight=0.0)
    for _ in range(50):
        est.observe_exits([0.2, 0.3], key="a")
        est.observe_exits([0.8, 0.9], key="b")
    assert est.curve("a")[1] < 0.4 < 0.8 <= est.curve("b")[1]
    assert sorted(est.keys()) == ["a", "b"]


def test_estimator_decay_forgets_the_old_regime():
    est = OnlineCurveEstimator(num_stages=1, prior=[0.5], decay=0.1,
                               prior_weight=0.0)
    for _ in range(200):
        est.observe(1, 0.9)
    for _ in range(100):
        est.observe(1, 0.3)            # regime shift
    assert est.curve()[0] < 0.35       # ~10-obs window: old regime gone


def test_estimator_curve_is_prior_blended_pseudo_count():
    est = OnlineCurveEstimator(num_stages=1, prior=[0.5], decay=0.0,
                               prior_weight=4.0)
    est.observe(1, 1.0)
    # (1.0 + 4 * 0.5) / (1 + 4)
    assert est.curve()[0] == pytest.approx(3.0 / 5.0)


def test_estimator_round_trips_through_json():
    est = OnlineCurveEstimator(num_stages=3, prior=[0.4, 0.6, 0.8])
    for _ in range(30):
        est.observe_exits([0.5, 0.7, 0.9])            # global (None) key
        est.observe_exits([0.3, 0.5, 0.6], key="llm")
    d = json.loads(json.dumps(est.to_dict()))
    back = OnlineCurveEstimator.from_dict(d)
    for key in (None, "llm"):
        assert np.allclose(back.curve(key), est.curve(key))
        assert np.allclose(back.weight(key), est.weight(key))


def test_estimator_validates_inputs():
    with pytest.raises(ValueError, match="num_stages"):
        OnlineCurveEstimator(num_stages=0)
    with pytest.raises(ValueError, match="decay"):
        OnlineCurveEstimator(num_stages=2, decay=1.0)
    with pytest.raises(ValueError, match="entries"):
        OnlineCurveEstimator(num_stages=2, prior=[0.5])
    est = OnlineCurveEstimator(num_stages=2)
    with pytest.raises(ValueError, match="depth"):
        est.observe(3, 0.5)


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=4),
                          st.floats(min_value=0.0, max_value=1.0)),
                max_size=60))
@settings(max_examples=25, deadline=None)
def test_estimator_curve_always_monotone_in_unit_interval(obs):
    """Property: whatever (depth, conf) sequence is observed, every
    readable curve stays monotone non-decreasing inside [0, 1] — the
    shape the FPTAS utility tables require."""
    est = OnlineCurveEstimator(num_stages=4, decay=0.05)
    for depth, conf in obs:
        est.observe(depth, conf)
    c = est.curve()
    assert np.all((0.0 <= c) & (c <= 1.0))
    assert np.all(np.diff(c) >= 0)


# ---------------------------------------------------------------------------
# AdaptivePredictor / AdaptiveRTDeepIoT
# ---------------------------------------------------------------------------

def test_adaptive_predictor_measured_prefix_wins():
    est = OnlineCurveEstimator(num_stages=3, prior=[0.4, 0.6, 0.8])
    pred = AdaptivePredictor(est)
    t = mk_task(deadline=1.0)
    t.executed, t.confidences = 2, [0.33, 0.44]
    assert pred.predict(t, 1) == pytest.approx(0.33)
    assert pred.predict(t, 2) == pytest.approx(0.44)


def test_adaptive_predictor_suffix_is_monotone_and_anchored():
    est = OnlineCurveEstimator(num_stages=3, prior=[0.4, 0.6, 0.8],
                               prior_weight=1.0)
    pred = AdaptivePredictor(est)
    t = mk_task(deadline=1.0)
    t.executed, t.confidences = 1, [0.9]      # task runs hot vs the curve
    p2, p3 = pred.predict(t, 2), pred.predict(t, 3)
    assert 0.9 <= p2 <= p3 <= 1.0             # never below last measured
    # fresh task with no measurements reads the curve directly
    t2 = mk_task(deadline=1.0)
    assert pred.predict(t2, 3) == pytest.approx(est.curve()[2])


def test_adaptive_policy_is_registered_and_learns_through_service():
    assert "rtdeepiot-adaptive" in available("policy")
    conf, correct = oracle_tables()
    est = OnlineCurveEstimator(num_stages=3, prior=conf.mean(0))
    spec = scenario_spec("steady", policy="rtdeepiot-adaptive",
                         stage_times=STAGE_TIMES, n_requests=60, seed=2)
    res = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                            curve_estimator=est).run()
    assert res.n_requests == 60
    assert est.n_observed > 0                 # stage exits fed the tables
    w1 = est.n_observed
    # the same resource keeps its warmth across a rebuild
    res2 = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                             curve_estimator=est).run()
    assert res2.n_requests == 60
    assert est.n_observed > w1


def test_adaptive_scheduler_observes_before_replanning():
    est = OnlineCurveEstimator(num_stages=3, prior=[0.4, 0.6, 0.8],
                               prior_weight=0.0)
    sched = AdaptiveRTDeepIoT(est, key_fn=lambda t: t.model)
    t = mk_task(deadline=1.0, model="llm")
    t.executed, t.confidences = 1, [0.77]
    sched.on_stage_done([t], t, now=0.01)
    assert est.weight("llm")[0] == pytest.approx(1.0)
    assert est.curve("llm")[0] == pytest.approx(0.77)


# ---------------------------------------------------------------------------
# PredictiveAdmissionController
# ---------------------------------------------------------------------------

def _tm():
    return BatchTimeModel.linear(STAGE_TIMES, (1,))


def test_predictive_without_process_matches_reactive_base():
    tm = _tm()
    base = PredictiveAdmissionController(tm, mode="depth_cap")
    t = mk_task(deadline=0.5)
    dec = base.decide([], t, 0.0)
    assert dec.admitted and dec.reason in ("ok", "deadline-capped")
    assert base.forecasted == 0


def test_forecast_below_capacity_changes_nothing():
    proc = make_arrival_process("poisson", rate=1.0)   # way under capacity
    ctl = PredictiveAdmissionController(_tm(), mode="depth_cap",
                                        process=proc)
    dec = ctl.decide([], mk_task(deadline=5.0), 0.0)
    assert dec.reason == "ok" and ctl.forecasted == 0


def test_forecast_capped_pins_to_mandatory_with_detail():
    nominal = 1.0 / sum(STAGE_TIMES)
    proc = make_arrival_process("poisson", rate=nominal * 3)
    ctl = PredictiveAdmissionController(_tm(), mode="depth_cap",
                                        process=proc, horizon=0.2)
    t = mk_task(deadline=5.0, mandatory=1)
    dec = ctl.decide([], t, 0.0)
    assert dec.admitted and dec.depth_cap == 1
    assert dec.reason == "forecast-capped"
    for k in ("forecast_rate", "capacity", "margin", "horizon", "slack"):
        assert k in dec.detail
    assert dec.detail["forecast_rate"] == pytest.approx(nominal * 3)
    assert ctl.forecasted == 1


def test_forecast_overload_rejects_when_slack_cannot_absorb_burst():
    nominal = 1.0 / sum(STAGE_TIMES)
    proc = make_arrival_process("poisson", rate=nominal * 20)
    ctl = PredictiveAdmissionController(_tm(), mode="reject",
                                        process=proc, horizon=0.5)
    tight = mk_task(deadline=0.08, mandatory=2)
    dec = ctl.decide([], tight, 0.0)
    assert not dec.admitted and dec.reason == "forecast-overload"
    assert dec.detail["expected_work"] > 0
    # a very lax deadline absorbs the same burst
    lax = mk_task(deadline=50.0, mandatory=2)
    assert ctl.decide([], lax, 0.0).admitted


def test_forecast_margin_gates_the_rule():
    nominal = 1.0 / sum(STAGE_TIMES)
    proc = make_arrival_process("poisson", rate=nominal * 1.5)
    loose = PredictiveAdmissionController(_tm(), mode="depth_cap",
                                          process=proc, margin=2.0)
    assert loose.decide([], mk_task(deadline=5.0), 0.0).reason == "ok"
    tight = PredictiveAdmissionController(_tm(), mode="depth_cap",
                                         process=proc, margin=1.0)
    assert tight.decide([], mk_task(deadline=5.0),
                        0.0).reason == "forecast-capped"


def test_forecast_rate_mmpp_falls_back_to_mean_rate():
    proc = make_arrival_process("mmpp", **ARRIVAL_CONFIGS["mmpp"])
    ctl = PredictiveAdmissionController(_tm(), process=proc)
    assert ctl.forecast_rate(0.0) == pytest.approx(proc.mean_rate)


def test_forecast_rate_leads_a_flash_crowd():
    proc = make_arrival_process("flash-crowd", base_rate=10.0,
                                spike_rate=500.0, spike_at=1.0,
                                spike_len=0.5)
    ctl = PredictiveAdmissionController(_tm(), process=proc, horizon=0.3)
    assert ctl.forecast_rate(0.2) == pytest.approx(10.0)
    assert ctl.forecast_rate(0.85) > 100.0    # sees the spike coming


def test_from_config_parses_spec_dict_and_defaults_capacity():
    fc = {"process": {"kind": "poisson", "rate": 9.0}, "horizon": 0.4,
          "margin": 1.25}
    ctl = PredictiveAdmissionController.from_config(
        _tm(), {"mode": "reject", "forecast": fc})
    assert ctl.mode == "reject"
    assert (ctl.horizon, ctl.margin) == (0.4, 1.25)
    assert ctl.capacity == pytest.approx(1.0 / sum(STAGE_TIMES))
    assert ctl.process.mean_rate == pytest.approx(9.0)


def test_predictive_admission_factory_composes_with_the_zoo():
    from repro.serving.zoo import ZooAdmissionController
    fc = {"process": {"kind": "poisson", "rate": 9.0}}
    ctl = predictive_admission(_tm(), {"mode": "depth_cap", "forecast": fc},
                               base_cls=ZooAdmissionController)
    assert isinstance(ctl, PredictiveAdmissionController)
    assert isinstance(ctl, ZooAdmissionController)


def test_spec_validates_forecast_shape():
    base = scenario_spec("steady", stage_times=STAGE_TIMES, n_requests=8)
    bad = dataclasses.replace(base, admission={"forecast": {"horizon": 1}})
    with pytest.raises(ValueError, match="forecast"):
        bad.validate()
    worse = dataclasses.replace(
        base, admission={"forecast": {"process": {"kind": "nope"}}})
    with pytest.raises(ValueError, match="forecast"):
        worse.validate()
    ok = dataclasses.replace(
        base,
        admission={"forecast": {"process": {"kind": "poisson", "rate": 5}}})
    ok.validate()


def test_forecast_decisions_reach_the_audit_log():
    """End-to-end: a flash-crowd run with a fitted forecast leaves
    forecast-capped rows in the obs audit log, numbers attached."""
    conf, correct = oracle_tables()
    nominal = 1.0 / sum(STAGE_TIMES)
    fc = {"process": {"kind": "flash-crowd", "base_rate": 0.2 * nominal,
                      "spike_rate": 5.0 * nominal, "spike_at": 0.3,
                      "spike_len": 0.5},
          "horizon": 0.25}
    spec = scenario_spec("flash-crowd", stage_times=STAGE_TIMES,
                         n_requests=120, seed=3,
                         admission={"mode": "depth_cap", "forecast": fc},
                         trace={"enabled": True})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    res = svc.run()
    assert res.capped > 0
    rows = [r for r in svc.obs.audit_log if r["rule"] == "forecast-capped"]
    assert rows, "forecast rule never fired during the spike"
    for r in rows:
        assert r["detail"]["forecast_rate"] > r["detail"]["capacity"]
        assert r["detail"]["horizon"] == pytest.approx(0.25)


def test_forecast_only_admission_defaults_to_depth_cap():
    conf, correct = oracle_tables()
    nominal = 1.0 / sum(STAGE_TIMES)
    fc = {"process": {"kind": "poisson", "rate": 3.0 * nominal}}
    spec = scenario_spec("steady", stage_times=STAGE_TIMES, n_requests=40,
                         seed=0, admission={"forecast": fc})
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    assert res.capped == res.n_requests       # every admit forecast-capped


# ---------------------------------------------------------------------------
# TrafficDriver (wall-clock)
# ---------------------------------------------------------------------------

def _live_spec():
    return ServeSpec(
        policy="edf", executor="oracle", clock="wall", source="live",
        batching={"mode": "none", "stage_times": [0.001, 0.001, 0.001]},
        slo_classes={"gold": {"rel_deadline": 2.0}}, default_slo="gold")


def test_driver_materialization_matches_the_virtual_source():
    """Same (arrival, mix, seed) -> the driver's pre-materialized stream
    carries exactly the offsets the virtual-clock source would."""
    arrival = {"kind": "poisson", "rate": 50.0}
    svc = object()                               # never submitted to
    drv = TrafficDriver(svc, arrival=dict(arrival), n_samples=32,
                        n_requests=24, seed=7)
    proc = make_arrival_process(**arrival)
    want = proc.sample(np.random.default_rng(7), n=24)
    got = [off for off, _req in drv.stream]
    assert np.allclose(got, want)
    # and twice the same seed -> identical requests
    drv2 = TrafficDriver(svc, arrival=dict(arrival), n_samples=32,
                         n_requests=24, seed=7)
    assert [r.sample for _o, r in drv.stream] \
        == [r.sample for _o, r in drv2.stream]


def test_driver_argument_validation():
    svc = object()
    with pytest.raises(ValueError, match="speed"):
        TrafficDriver(svc, offsets=[0.0], n_samples=4, speed=0.0)
    with pytest.raises(ValueError, match="arrival"):
        TrafficDriver(svc, n_samples=4)
    with pytest.raises(ValueError, match="n_requests"):
        TrafficDriver(svc, arrival={"kind": "poisson", "rate": 5.0},
                      n_samples=4)
    with pytest.raises(ValueError, match="n_samples"):
        TrafficDriver(svc, offsets=[0.0, 0.1])


@pytest.mark.wallclock
def test_driver_paces_submissions_into_a_live_service():
    from conftest import wait_until
    conf, correct = oracle_tables()
    with Service.from_spec(_live_spec(), conf_table=conf,
                           correct_table=correct) as svc:
        drv = TrafficDriver(svc, arrival={"kind": "poisson", "rate": 200.0},
                            n_samples=conf.shape[0], n_requests=25, seed=1,
                            speed=4.0).start()
        assert drv.join(timeout=30.0)
        assert drv.submitted == 25
        wait_until(lambda: all(h.done() for h in drv.handles),
                   desc="all driver handles resolved")
        met = svc.drain()
    assert met.n_requests == 25


@pytest.mark.wallclock
def test_driver_stop_aborts_pacing_quickly():
    conf, correct = oracle_tables()
    with Service.from_spec(_live_spec(), conf_table=conf,
                           correct_table=correct) as svc:
        # 10 rps unscaled: the full stream would take ~2s; stop instead
        drv = TrafficDriver(svc, arrival={"kind": "poisson", "rate": 10.0},
                            n_samples=conf.shape[0], n_requests=20,
                            seed=0).start()
        drv.stop()
        assert drv.join(timeout=10.0)
        assert drv.submitted < 20
        svc.drain()


@pytest.mark.wallclock
def test_driver_replays_a_recorded_trace_live(tmp_path):
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", stage_times=STAGE_TIMES, n_requests=12,
                         seed=5)
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    path = str(tmp_path / "t.jsonl")
    record_trace(res, path, source="traffic", spec=spec)
    _, events = load_trace(path)
    from repro.serving.traffic.scenarios import SLO_CLASSES
    live = dataclasses.replace(_live_spec(),
                               slo_classes=dict(SLO_CLASSES))
    with Service.from_spec(live, conf_table=conf,
                           correct_table=correct) as svc:
        drv = TrafficDriver.from_trace(svc, events, speed=8.0)
        assert drv.run() == 12
        met = svc.drain()
    assert met.n_requests == 12
    assert sorted(r["sample"] for r in met.per_request) \
        == sorted(r["sample"] for r in res.per_request)


# ---------------------------------------------------------------------------
# the loop closed: record -> fit -> forecast beats reactive on the replay
# ---------------------------------------------------------------------------

def test_fitted_forecast_arms_admission_from_yesterdays_trace():
    """The adaptive story end to end on the virtual clock: record a
    flash-crowd day, fit it, arm admission with the fit, and replay a
    different seed of the same process — the forecast rule fires."""
    conf, correct = oracle_tables()
    # enough requests that the spike *ends* inside the trace — on a
    # spike-truncated record an on/off MMPP explains the data just as well
    rec_spec = scenario_spec("flash-crowd", stage_times=STAGE_TIMES,
                             n_requests=600, seed=11)
    rec = Service.from_spec(rec_spec, conf_table=conf,
                            correct_table=correct).run()
    fit = fit_report([r["offset"] for r in rec.per_request])
    assert fit["best"] == "flash-crowd"
    spec = scenario_spec(
        "flash-crowd", stage_times=STAGE_TIMES, n_requests=150, seed=12,
        admission={"mode": "depth_cap",
                   "forecast": {"process": fit["fits"][fit["best"]],
                                "horizon": 0.25}},
        trace={"enabled": True})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    res = svc.run()
    assert res.n_requests == 150
    assert any(r["rule"] == "forecast-capped" for r in svc.obs.audit_log)
