"""Per-kernel allclose validation against the pure-jnp oracles.

Each Pallas kernel is swept over shapes / dtypes / masking configs in
interpret mode (executes the kernel body on CPU) and asserted against its
ref.py oracle, per the assignment's kernel-testing requirement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.exit_confidence import exit_confidence, exit_confidence_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,dh", [
    (1, 2, 2, 32, 16),       # MHA
    (2, 4, 2, 64, 32),       # GQA 2:1
    (1, 8, 1, 128, 64),      # MQA
    (2, 6, 2, 48, 32),       # ragged seq vs block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
def test_flash_attention_sweep(B, H, KV, S, dh, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, S, dh), dtype)
    k = _rand(ks[1], (B, KV, S, dh), dtype)
    v = _rand(ks[2], (B, KV, S, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_block_shape_independence():
    """Result must not depend on BlockSpec tile choice."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 2, 64, 32), jnp.float32)
    k = _rand(ks[1], (1, 2, 64, 32), jnp.float32)
    v = _rand(ks[2], (1, 2, 64, 32), jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(8, 8), (16, 32), (64, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,dh", [
    (2, 4, 2, 40, 32),
    (1, 8, 8, 64, 16),
    (3, 6, 1, 33, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 8])
def test_decode_attention_sweep(B, H, KV, S, dh, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, H, dh), dtype)
    k = _rand(ks[1], (B, KV, S, dh), dtype)
    v = _rand(ks[2], (B, KV, S, dh), dtype)
    slot_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    slot_pos = slot_pos.at[:, -3:].set(-1)       # unwritten slots
    cur = jnp.arange(B) * 7 + 10
    out = decode_attention(q, k, v, slot_pos, cur, window=window, block_k=16)
    ref = decode_attention_ref(q, k, v, slot_pos, cur, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_ring_semantics():
    """Non-monotonic slot_pos (ring cache) must mask exactly."""
    B, H, KV, S, dh = 1, 2, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, H, dh), jnp.float32)
    k = _rand(ks[1], (B, KV, S, dh), jnp.float32)
    v = _rand(ks[2], (B, KV, S, dh), jnp.float32)
    # ring of 16 slots after 20 tokens: positions 4..19 wrapped
    slot_pos = jnp.array([[(16 + i) if i < 4 else i for i in range(S)]])
    cur = jnp.array([19])
    out = decode_attention(q, k, v, slot_pos, cur, block_k=8)
    ref = decode_attention_ref(q, k, v, slot_pos, cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# exit confidence (fused norm + proj + online softmax max)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,d,V", [(4, 32, 100), (8, 64, 1000),
                                   (3, 128, 517), (16, 64, 32768)])
@pytest.mark.parametrize("temperature", [1.0, 2.0])
def test_exit_confidence_sweep(N, d, V, temperature):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    h = _rand(ks[0], (N, d), jnp.float32)
    scale = 0.1 * _rand(ks[1], (d,), jnp.float32)
    w = 0.3 * _rand(ks[2], (d, V), jnp.float32)
    conf, pred, m, lse = exit_confidence(h, scale, w, temperature=temperature,
                                         block_rows=4, block_v=128)
    rconf, rpred, rm, rlse = exit_confidence_ref(h, scale, w,
                                                 temperature=temperature)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(rconf), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(rpred))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(rlse), atol=1e-4)
    assert bool((conf <= 1.0 + 1e-6).all()) and bool((conf > 0).all())


def test_exit_confidence_matches_model_head():
    """Kernel agrees with the model's exit head + confidence_from_logits."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.common import rms_norm
    from repro.models.exits import confidence_from_logits

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    h = _rand(jax.random.PRNGKey(5), (6, cfg.d_model), jnp.float32)
    ln = params["exits"][0]["ln"]
    w = params["exit_shared"]["w_out"]
    conf, pred, _, _ = exit_confidence(h, ln, w, block_rows=2, block_v=64)
    logits = rms_norm(h, ln, cfg.norm_eps) @ w
    ref_conf = confidence_from_logits(logits)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(ref_conf),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(jnp.argmax(logits, -1)))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,d", [(8, 32), (37, 64), (256, 128), (5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(N, d, dtype):
    x = _rand(jax.random.PRNGKey(6), (N, d), dtype)
    s = 0.1 * _rand(jax.random.PRNGKey(7), (d,), jnp.float32).astype(dtype)
    out = rmsnorm(x, s, block_rows=16)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# mLSTM chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,L,dh", [(1, 2, 8, 8), (2, 4, 16, 16),
                                      (2, 2, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_sweep(B, H, L, dh, dtype):
    from repro.kernels.mlstm_chunk import mlstm_chunk, mlstm_chunk_ref
    ks = jax.random.split(jax.random.PRNGKey(8), 7)
    q = _rand(ks[0], (B, H, L, dh), dtype)
    k = _rand(ks[1], (B, H, L, dh), dtype)
    v = _rand(ks[2], (B, H, L, dh), dtype)
    i_pre = _rand(ks[3], (B, H, L), jnp.float32)
    f_pre = _rand(ks[4], (B, H, L), jnp.float32) + 2.0
    C0 = 0.1 * _rand(ks[5], (B, H, dh, dh), jnp.float32)
    n0 = 0.1 * _rand(ks[6], (B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H))
    out = mlstm_chunk(q, k, v, i_pre, f_pre, C0, n0, m0)
    ref = mlstm_chunk_ref(q, k, v, i_pre, f_pre, C0, n0, m0)
    # the kernel accumulates fully in fp32 while the jnp reference keeps the
    # intra-chunk matmul in the input dtype -> small bf16 divergence on
    # near-cancelling normalizers
    tol = 8e-2 if dtype == jnp.bfloat16 else TOL[dtype]
    for a, b, nm in zip(out, ref, ("h", "C1", "n1", "m1")):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol, err_msg=nm)


def test_mlstm_chunk_state_chaining():
    """Two kernel chunks chained == one double-length reference chunk."""
    from repro.kernels.mlstm_chunk import mlstm_chunk, mlstm_chunk_ref
    B, H, L, dh = 1, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    q = _rand(ks[0], (B, H, 2 * L, dh), jnp.float32)
    k = _rand(ks[1], (B, H, 2 * L, dh), jnp.float32)
    v = _rand(ks[2], (B, H, 2 * L, dh), jnp.float32)
    i_pre = _rand(ks[3], (B, H, 2 * L), jnp.float32)
    f_pre = _rand(ks[4], (B, H, 2 * L), jnp.float32) + 2.0
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.full((B, H), -1e30)
    h1, C1, n1, m1 = mlstm_chunk(q[:, :, :L], k[:, :, :L], v[:, :, :L],
                                 i_pre[:, :, :L], f_pre[:, :, :L],
                                 C0, n0, m0)
    h2, C2, n2, m2 = mlstm_chunk(q[:, :, L:], k[:, :, L:], v[:, :, L:],
                                 i_pre[:, :, L:], f_pre[:, :, L:],
                                 C1, n1, m1)
    href, Cref, nref, mref = mlstm_chunk_ref(q, k, v, i_pre, f_pre,
                                             C0, n0, m0)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 2)),
                               np.asarray(href), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(C2), np.asarray(Cref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mref), atol=1e-5)
