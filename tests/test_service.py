"""Tests for the public serving API (repro.serving.service / .registry).

* ServeSpec round trip (dict + JSON) and validation errors
* registry: custom policy registered and served end-to-end without
  touching core modules; component-instance resources skip the registry
* one-shot DeprecationWarnings on all four legacy entry points
* SLO classes, ResponseHandle futures (result / stages / cancel) in both
  virtual-buffered and wall-clock live modes
* ServiceMetrics superset (per-class, admission counts, to_json) and
  SimResult.to_dict
* AdmissionController decision boundaries and StreamSource zero-slack /
  simultaneous-arrival ordering (previously untested edges)
"""
import json
import warnings
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import EDF, Task, Workload, simulate
from repro.core.schedulers import Policy
from repro.serving import (AdmissionController, BatchTimeModel, Request,
                           ServeSpec, Service, simulate_batched)
from repro.serving.deprecation import _reset as reset_deprecations
from repro.serving.registry import available, register_policy, resolve
from repro.serving.runtime.sources import StreamSource

STAGE_TIMES = (0.004, 0.007, 0.010)


def oracle_tables(n=120, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def base_spec(**overrides):
    kw = dict(policy="edf", executor="oracle", clock="virtual",
              source="closed-loop",
              batching={"mode": "none", "stage_times": list(STAGE_TIMES)})
    kw.update(overrides)
    return ServeSpec(**kw)


# ---------------------------------------------------------------------------
# ServeSpec round trip + validation
# ---------------------------------------------------------------------------

def test_servespec_round_trips_dict_and_json():
    spec = ServeSpec(
        policy="rtdeepiot", policy_args={"predictor": "exp", "delta": 0.05},
        executor="oracle", clock="virtual", source="closed-loop",
        batching={"buckets": [1, 2, 4], "marginal": 0.2,
                  "stage_times": list(STAGE_TIMES)},
        admission={"mode": "depth_cap", "headroom": 1.2},
        slo_classes={"gold": {"rel_deadline": 0.5, "utility_weight": 2.0},
                     "bronze": {"rel_deadline": 0.05, "depth_cap": 1}},
        default_slo="gold", pipeline_depth=2, dispatch_overhead=1e-4,
        policy_cost=5e-4, charge_overhead=True, host_overhead=1e-5)
    d = spec.to_dict()
    assert ServeSpec.from_dict(d) == spec
    assert ServeSpec.from_json(spec.to_json()) == spec
    assert json.loads(spec.to_json())["slo_classes"]["bronze"]["depth_cap"] == 1


def test_servespec_rejects_unknown_keys_and_bad_values():
    with pytest.raises(ValueError, match="unknown ServeSpec keys"):
        ServeSpec.from_dict({"policyy": "edf"})
    with pytest.raises(KeyError, match="no policy registered"):
        base_spec(policy="definitely-not-registered").validate()
    with pytest.raises(ValueError, match="pipeline_depth"):
        base_spec(pipeline_depth=0).validate()
    with pytest.raises(ValueError, match="admission mode"):
        base_spec(admission={"mode": "maybe"}).validate()
    with pytest.raises(ValueError, match="rel_deadline"):
        base_spec(slo_classes={"gold": {"rel_deadline": -1}}).validate()
    with pytest.raises(ValueError, match="default_slo"):
        base_spec(default_slo="gold").validate()


def test_registry_resolve_errors_list_available():
    with pytest.raises(KeyError, match="available"):
        resolve("executor", "nope")
    assert "oracle" in available("executor")
    assert {"rtdeepiot", "edf", "lcf", "rr"} <= set(available("policy"))


# ---------------------------------------------------------------------------
# registry: custom policy end-to-end (no core modules touched)
# ---------------------------------------------------------------------------

def test_registry_custom_policy_end_to_end():
    class DeepestFirst(Policy):
        """Always finish the most-advanced task first."""
        name = "deepest-first"

        def next_task(self, active, now):
            r = self._runnable(active, now)
            return max(r, key=lambda t: (t.executed, -t.tid)) if r else None

    register_policy("test-deepest-first", lambda args, ctx: DeepestFirst())
    conf, correct = oracle_tables()
    wl = Workload(n_clients=6, d_lo=0.05, d_hi=0.3, n_requests=40, seed=3)
    spec = base_spec(policy="test-deepest-first")
    res = Service.from_spec(spec, workload=wl, conf_table=conf,
                            correct_table=correct).run()
    assert res.n_requests == 40
    assert res.miss_rate < 1.0
    assert res.components["policy"] == "test-deepest-first"


# ---------------------------------------------------------------------------
# one-shot deprecation warnings on the legacy entry points
# ---------------------------------------------------------------------------

def _assert_warns_exactly_once(fn):
    with pytest.warns(DeprecationWarning, match="ServeSpec") as rec:
        fn()
    assert sum(issubclass(r.category, DeprecationWarning)
               for r in rec) == 1
    with warnings.catch_warnings():           # second call: silent
        warnings.simplefilter("error", DeprecationWarning)
        fn()


def test_simulate_warns_once():
    conf, correct = oracle_tables(n=20)
    wl = Workload(n_clients=2, n_requests=6, seed=0)
    reset_deprecations()
    _assert_warns_exactly_once(
        lambda: simulate(EDF(), wl, STAGE_TIMES, conf, correct))


def test_simulate_batched_warns_once():
    conf, correct = oracle_tables(n=20)
    wl = Workload(n_clients=2, n_requests=6, seed=0)
    tm = BatchTimeModel.linear(STAGE_TIMES, (1, 2))
    reset_deprecations()
    _assert_warns_exactly_once(
        lambda: simulate_batched(EDF(), wl, tm, conf, correct))


def test_wall_clock_engines_warn_once():
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import BatchedServingEngine, ServingEngine

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tm = BatchTimeModel.linear((0.001,) * cfg.num_stages, (1, 2))
    eng_s = ServingEngine(cfg, params, EDF(),
                          stage_wcet=(0.001,) * cfg.num_stages)
    eng_b = BatchedServingEngine(cfg, params, EDF(), time_model=tm)
    reset_deprecations()
    # an empty stream exercises the deprecation path without serving work
    _assert_warns_exactly_once(lambda: eng_s.run([]))
    _assert_warns_exactly_once(lambda: eng_b.run([]))


# ---------------------------------------------------------------------------
# SLO classes + futures (virtual-buffered live mode)
# ---------------------------------------------------------------------------

SLO_SPEC = dict(
    policy="edf", executor="oracle", clock="virtual", source="live",
    batching={"mode": "none", "stage_times": list(STAGE_TIMES)},
    slo_classes={"gold": {"rel_deadline": 0.5, "utility_weight": 2.0},
                 "bronze": {"rel_deadline": 0.05, "depth_cap": 1}},
    default_slo="gold")


def test_slo_classes_and_futures_virtual():
    conf, correct = oracle_tables()
    svc = Service.from_spec(ServeSpec(**SLO_SPEC), conf_table=conf,
                            correct_table=correct)
    h_gold = svc.submit(Request(None, sample=3), at=0.0)
    h_bronze = svc.submit(Request(None, sample=7), slo="bronze", at=0.0)
    assert not h_gold.done()
    met = svc.drain()
    r_gold, r_bronze = h_gold.result(), h_bronze.result()
    # gold: generous deadline, full depth, weight applied to the task
    assert r_gold.depth == 3 and r_gold.slo == "gold"
    assert h_gold._task.weight == 2.0
    # bronze: depth-capped at 1 by its SLO class
    assert r_bronze.depth == 1 and r_bronze.slo == "bronze"
    assert h_bronze._task.depth_cap == 1
    # stages(): one StageExit per in-time anytime exit, in depth order
    exits = list(h_gold.stages())
    assert [e.depth for e in exits] == [1, 2, 3]
    assert all(0.0 <= e.confidence <= 1.0 for e in exits)
    assert [e.depth for e in h_bronze.stages()] == [1]
    # per-class metrics
    assert met.per_class["gold"]["n"] == 1
    assert met.per_class["bronze"]["mean_depth"] == 1.0
    assert met.components["source"] == "live"


def test_submit_unknown_slo_rejected_and_cancel():
    conf, correct = oracle_tables()
    svc = Service.from_spec(ServeSpec(**SLO_SPEC), conf_table=conf,
                            correct_table=correct)
    with pytest.raises(KeyError, match="unknown SLO class"):
        svc.submit(Request(None, sample=0), slo="platinum")
    h1 = svc.submit(Request(None, sample=1), at=0.0)
    h2 = svc.submit(Request(None, sample=2), at=0.0)
    assert h2.cancel() and h2.cancelled()
    assert not h2.cancel()                      # already cancelled
    met = svc.drain()
    assert h1.result().depth == 3
    with pytest.raises(CancelledError):
        h2.result()
    assert not h1.cancel()                      # already resolved
    assert met.n_requests == 1 and met.cancelled == 1
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(Request(None, sample=3))


def test_run_refuses_live_source_and_submit_refuses_batch_source():
    conf, correct = oracle_tables()
    svc = Service.from_spec(ServeSpec(**SLO_SPEC), conf_table=conf,
                            correct_table=correct)
    with pytest.raises(RuntimeError, match="submit"):
        svc.run()
    svc2 = Service.from_spec(base_spec(), conf_table=conf,
                             correct_table=correct)
    with pytest.raises(RuntimeError, match="live"):
        svc2.submit(Request(None, sample=0))


# ---------------------------------------------------------------------------
# wall-clock live mode (background engine thread, oracle executor)
# ---------------------------------------------------------------------------

@pytest.mark.wallclock
def test_live_wall_clock_service_serves_submissions():
    conf, correct = oracle_tables()
    spec = ServeSpec(
        policy="edf", executor="oracle", clock="wall", source="live",
        batching={"mode": "none", "stage_times": [0.002, 0.002, 0.002]},
        slo_classes={"gold": {"rel_deadline": 0.5}}, default_slo="gold")
    with Service.from_spec(spec, conf_table=conf,
                           correct_table=correct) as svc:
        handles = [svc.submit(Request(None, sample=i)) for i in range(6)]
        results = [h.result(timeout=10.0) for h in handles]
        assert all(r.depth == 3 and not r.missed for r in results)
        # streaming exits landed for every request
        assert all([e.depth for e in h.stages()] == [1, 2, 3]
                   for h in handles)
        met = svc.drain()
    assert met.n_requests == 6
    assert met.miss_rate == 0.0
    assert met.makespan > 0.0


@pytest.mark.wallclock
def test_live_engine_failure_fans_out_to_handles():
    """An engine-thread crash must not strand result() waiters: every
    outstanding handle unblocks with the error, and drain() re-raises."""
    from repro.serving import OracleExecutor

    class ExplodingExecutor(OracleExecutor):
        def submit(self, stage, tasks, now):
            raise RuntimeError("boom")

    conf, correct = oracle_tables()
    tm = BatchTimeModel.linear(STAGE_TIMES, (1,))
    spec = ServeSpec(
        policy="edf", executor="oracle", clock="wall", source="live",
        slo_classes={"gold": {"rel_deadline": 0.5}}, default_slo="gold")
    svc = Service.from_spec(spec, executor=ExplodingExecutor(tm, conf),
                            time_model=tm, conf_table=conf,
                            correct_table=correct)
    h = svc.submit(Request(None, sample=0))
    with pytest.raises(RuntimeError, match="engine failed"):
        h.result(timeout=10.0)
    with pytest.raises(RuntimeError, match="failed while live"):
        svc.drain()


def test_submit_without_any_deadline_fails_fast():
    conf, correct = oracle_tables()
    spec = ServeSpec(
        policy="edf", executor="oracle", clock="virtual", source="live",
        batching={"mode": "none", "stage_times": list(STAGE_TIMES)})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    with pytest.raises(ValueError, match="no rel_deadline"):
        svc.submit(Request(None, sample=0))      # no SLO classes defined


# ---------------------------------------------------------------------------
# metrics: superset structure + JSON export
# ---------------------------------------------------------------------------

def test_service_metrics_superset_and_json():
    conf, correct = oracle_tables()
    wl = Workload(n_clients=16, d_lo=0.01, d_hi=0.1, n_requests=60, seed=1)
    spec = base_spec(
        batching={"buckets": [1, 2, 4], "stage_times": list(STAGE_TIMES)},
        admission={"mode": "reject"})
    met = Service.from_spec(spec, workload=wl, conf_table=conf,
                            correct_table=correct).run()
    assert met.rejected > 0                    # overloaded: reject mode bites
    assert met.row()["accuracy"] == met.accuracy     # SimResult surface
    d = json.loads(met.to_json())
    assert d["components"] == dict(policy="edf", executor="oracle",
                                   clock="virtual", source="closed-loop")
    assert d["rejected"] == met.rejected
    assert "per_request" not in d
    full = met.to_dict(per_request=True)
    assert len(full["per_request"]) == met.n_requests


def test_simresult_to_dict():
    conf, correct = oracle_tables(n=20)
    wl = Workload(n_clients=2, n_requests=6, seed=0)
    res = simulate(EDF(), wl, STAGE_TIMES, conf, correct)
    d = res.to_dict()
    assert d["accuracy"] == res.accuracy and "per_request" not in d
    assert set(d) >= {"miss_rate", "makespan", "throughput", "sched_charged"}


# ---------------------------------------------------------------------------
# AdmissionController decision boundaries (satellite)
# ---------------------------------------------------------------------------

def adm_tm():
    return BatchTimeModel.linear(STAGE_TIMES, (1,))


def mk_task(deadline, *, now=0.0, mandatory=1):
    return Task(arrival=now, deadline=deadline, stage_times=STAGE_TIMES,
                mandatory=mandatory)


def test_admission_mandatory_infeasible_boundary():
    adm = AdmissionController(adm_tm(), mode="reject")
    # mandatory part solo = 0.004: just below is rejected ...
    dec = adm.decide([], mk_task(0.0039), 0.0)
    assert not dec.admitted and dec.reason == "mandatory-infeasible"
    # ... exactly equal is admitted (deadline met with zero slack)
    dec = adm.decide([], mk_task(0.004), 0.0)
    assert dec.admitted and dec.reason == "ok"


def test_admission_overload_reject_vs_depth_cap_boundary():
    # two active tasks owe their mandatory stage: backlog = 2 * 0.004 at
    # the best amortized rate; own mandatory = 0.004 -> pressure = 0.012
    active = [mk_task(1.0), mk_task(1.0)]
    t_in = mk_task(0.012)       # deadline == pressure: NOT overloaded (>)
    t_out = mk_task(0.0119)     # strictly inside: overloaded
    rej = AdmissionController(adm_tm(), mode="reject")
    cap = AdmissionController(adm_tm(), mode="depth_cap")
    dec = rej.decide(active, t_out, 0.0)
    assert not dec.admitted and dec.reason == "overload"
    dec = cap.decide(active, t_out, 0.0)
    assert dec.admitted and dec.depth_cap == t_out.mandatory
    assert dec.reason == "overload-capped"
    dec = rej.decide(active, t_in, 0.0)
    assert dec.admitted
    # headroom > 1 shifts the boundary: the equality case now rejects
    dec = AdmissionController(adm_tm(), mode="reject",
                              headroom=1.01).decide(active, t_in, 0.0)
    assert not dec.admitted and dec.reason == "overload"


def test_admission_depth_cap_solo_feasibility():
    cap = AdmissionController(adm_tm(), mode="depth_cap")
    # 0.004 / 0.011 / 0.021 cumulative: deadline 0.012 -> depth 2 only
    dec = cap.decide([], mk_task(0.012), 0.0)
    assert dec.admitted and dec.depth_cap == 2
    assert dec.reason == "deadline-capped"
    # deadline covers the full pipeline -> uncapped
    dec = cap.decide([], mk_task(0.021), 0.0)
    assert dec.admitted and dec.depth_cap is None and dec.reason == "ok"


def test_admission_apply_mutates_task_and_counters():
    cap = AdmissionController(adm_tm(), mode="depth_cap")
    t = mk_task(0.012)
    dec = cap.apply([], t, 0.0)
    assert dec.admitted and t.depth_cap == 2 and cap.capped == 1
    rej = AdmissionController(adm_tm(), mode="reject")
    t2 = mk_task(0.001)
    dec = rej.apply([], t2, 0.0)
    assert not dec.admitted and t2.dropped and rej.rejected == 1


# ---------------------------------------------------------------------------
# StreamSource edges (satellite)
# ---------------------------------------------------------------------------

def test_stream_source_simultaneous_arrivals_preserve_insertion_order():
    reqs = [(0.0, Request(None, 0.5, sample=10)),
            (0.0, Request(None, 0.5, sample=11)),
            (0.0, Request(None, 0.5, sample=12))]
    src = StreamSource(reqs, lambda req, now: req)
    assert src.has_pending() and src.next_time() == 0.0
    popped = [src.pop(0.0).sample for _ in range(3)]
    assert popped == [10, 11, 12]          # stable sort: insertion order
    assert not src.has_pending()
    assert src.next_time() == np.inf


def test_stream_source_zero_slack_request_is_counted_as_miss():
    """A request whose deadline equals its arrival (zero slack after the
    §II-B adjustment) must still flow through admit -> expire -> retire as
    a depth-0 miss, not be dropped silently; simultaneous arrivals keep
    insertion order in the task ids."""
    conf, correct = oracle_tables()
    spec = base_spec(source="stream")
    reqs = [(0.0, Request(None, 0.0, sample=1)),       # zero slack: miss
            (0.0, Request(None, 0.5, sample=2)),
            (0.01, Request(None, 0.5, sample=3))]
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run(reqs)
    assert res.n_requests == 3
    by_sample = {r["sample"]: r for r in res.per_request}
    assert by_sample[1]["missed"] and by_sample[1]["depth"] == 0
    assert not by_sample[2]["missed"] and not by_sample[3]["missed"]
    # the two t=0 arrivals were admitted in insertion order
    assert by_sample[1]["tid"] < by_sample[2]["tid"] < by_sample[3]["tid"]
