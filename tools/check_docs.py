"""docs-check: execute every fenced snippet, verify every internal link.

The documentation gate behind the `docs-check` CI job:

1. **Snippet execution** — every ```` ```python ```` fenced block in
   ``docs/*.md`` and ``README.md``, plus the fenced examples embedded in
   the public serving docstrings (``repro.serving.registry``,
   ``repro.serving.traffic.generators``), is executed.  Blocks within one
   file share a namespace (tutorials build up state); a block tagged
   ```` ```python no-run ```` is syntax-checked only (illustrative
   fragments: factory bodies, signatures).
2. **Internal links** — every relative markdown link target in the
   scanned files must exist on disk.
3. **Field coverage** — ``docs/serving-api.md`` must mention every
   ``ServeSpec`` field by name, so the reference table cannot drift from
   the dataclass.

Usage: ``PYTHONPATH=src python tools/check_docs.py [--quick]``
(``--quick`` skips snippet execution — links and coverage only).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import re
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md"))

#: (module, [attrs]) whose docstring examples are part of the public
#: contract — [] means the module docstring itself
DOCSTRING_MODULES = (
    ("repro.serving.registry", []),
    ("repro.serving.traffic.generators", []),
    ("repro.serving.service", ["ServeSpec", "Service", "ResponseHandle"]),
    ("repro.serving.obs", []),
)

FENCE = re.compile(r"^```python([^\n`]*)\n(.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)
LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")


def fenced_blocks(text: str):
    """(info, code) for every ```python fenced block."""
    return [(m.group(1).strip(), m.group(2)) for m in FENCE.finditer(text)]


def run_blocks(label: str, blocks, failures: list) -> int:
    """Execute ``blocks`` sequentially in one shared namespace."""
    ns: dict = {"__name__": f"docs_check::{label}"}
    n = 0
    for i, (info, code) in enumerate(blocks):
        where = f"{label} [snippet {i + 1}]"
        try:
            compiled = compile(code, where, "exec")
        except SyntaxError:
            failures.append((where, traceback.format_exc()))
            continue
        if "no-run" in info:
            continue
        try:
            exec(compiled, ns)          # noqa: S102 — that's the point
            n += 1
        except Exception:               # noqa: BLE001 — reported, not fatal here
            failures.append((where, traceback.format_exc()))
    return n


def check_links(path: str, text: str, failures: list) -> int:
    n = 0
    base = os.path.dirname(os.path.join(REPO, path))
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        n += 1
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            failures.append((path, f"broken link: {m.group(0)}"))
    return n


def check_spec_fields(failures: list) -> int:
    from repro.serving import ServeSpec
    with open(os.path.join(REPO, "docs", "serving-api.md")) as f:
        text = f.read()
    missing = [f.name for f in dataclasses.fields(ServeSpec)
               if f"`{f.name}`" not in text]
    if missing:
        failures.append(("docs/serving-api.md",
                         f"ServeSpec fields missing from the reference: "
                         f"{missing}"))
    return len(dataclasses.fields(ServeSpec))


def docstring_blocks(modname: str, attrs):
    import importlib
    import inspect
    mod = importlib.import_module(modname)

    def blocks(obj):
        return fenced_blocks(inspect.cleandoc(obj.__doc__ or ""))
    if not attrs:
        return [(f"{modname}.__doc__", blocks(mod))]
    return [(f"{modname}.{a}.__doc__", blocks(getattr(mod, a)))
            for a in attrs]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="links + field coverage only (no snippet runs)")
    args = ap.parse_args(argv)

    failures: list = []
    ran = links = 0
    for path in DOC_FILES:
        with open(os.path.join(REPO, path)) as f:
            text = f.read()
        links += check_links(path, text, failures)
        blocks = fenced_blocks(text)
        if args.quick:
            for i, (_, code) in enumerate(blocks):
                try:
                    compile(code, f"{path} [snippet {i + 1}]", "exec")
                except SyntaxError:
                    failures.append((f"{path} [snippet {i + 1}]",
                                     traceback.format_exc()))
            continue
        ran += run_blocks(path, blocks, failures)
    fields = check_spec_fields(failures)
    if not args.quick:
        for modname, attrs in DOCSTRING_MODULES:
            for label, blocks in docstring_blocks(modname, attrs):
                if not blocks:
                    failures.append((label, "no fenced example snippet"))
                ran += run_blocks(label, blocks, failures)

    for where, err in failures:
        print(f"FAIL {where}\n{err}\n", file=sys.stderr)
    status = "FAILED" if failures else "OK"
    print(f"docs-check {status}: {len(DOC_FILES)} files, {ran} snippets "
          f"executed, {links} links, {fields} ServeSpec fields checked, "
          f"{len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
