"""planectl: offline health/stats over a durable-plane journal and
observability exports.

The journal directory (``repro.serving.plane.Journal``) is the request
plane's source of truth, so this CLI needs no live process — it answers
the operator questions from the segments alone:

    PYTHONPATH=src python tools/planectl.py stats <journal_dir>
    PYTHONPATH=src python tools/planectl.py stats <journal_dir> --json
    PYTHONPATH=src python tools/planectl.py pending <journal_dir>
    PYTHONPATH=src python tools/planectl.py tail <journal_dir> [-n 10]

``stats`` — queue depth (durably submitted, not yet terminal),
per-tenant admit/retire/reject counts, the same breakdown per zoo model
(only when records carry ``model``), journal shape (segments, records,
last seq).  ``pending`` — the request_ids :func:`recover` would redo.
``tail`` — the last N records, one JSON line each.

Over an obs JSONL export (``ServeSpec(trace={"export": ...})`` or
``Tracer.export_jsonl``; see docs/observability.md):

    PYTHONPATH=src python tools/planectl.py trace <export> <request_id|tid>
    PYTHONPATH=src python tools/planectl.py why   <export> <request_id|tid>
    PYTHONPATH=src python tools/planectl.py top   <export> [-n 10] [--by X]

``trace`` — the request's typed spans, chronologically.  ``why`` — its
admission decision plus every audit-log rule that fired for it, with
the numbers behind the rule.  ``top`` — worst requests by latency /
queue_wait / device_time (``--by``), plus run totals.

A live process answers the same questions (plus in-memory queue state)
via ``FrontDoor.stats()`` / ``Service.obs``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.serving.plane.health import journal_stats          # noqa: E402
from repro.serving.plane.journal import scan_journal          # noqa: E402


def _cmd_stats(args) -> int:
    st = journal_stats(args.journal)
    if args.json:
        print(json.dumps(st, indent=2, sort_keys=True))
        return 0
    print(f"journal     {st['path']}")
    print(f"version     {st['version']}  source={st['source']}  "
          f"spec={'yes' if st['has_spec'] else 'no'}")
    print(f"segments    {st['segments']}  records={st['records']}  "
          f"last_seq={st['last_seq']}")
    print("counts      " + "  ".join(
        f"{k}={v}" for k, v in sorted(st["counts"].items())))
    print(f"queue_depth {st['queue_depth']}")
    for tenant, c in sorted(st["per_tenant"].items()):
        print(f"  tenant {tenant:<12} submitted={c['submitted']} "
              f"admitted={c['admitted']} staged={c['staged']} "
              f"retired={c['retired']} rejected={c['rejected']} "
              f"pending={c['pending']}")
    for model, c in sorted(st.get("per_model", {}).items()):
        print(f"  model  {model:<12} submitted={c['submitted']} "
              f"admitted={c['admitted']} staged={c['staged']} "
              f"retired={c['retired']} rejected={c['rejected']} "
              f"pending={c['pending']}")
    return 0


def _cmd_pending(args) -> int:
    st = journal_stats(args.journal)
    for rid in st["pending"]:
        print(rid)
    return 0 if not st["pending"] else 1


def _cmd_tail(args) -> int:
    _, records = scan_journal(args.journal)
    for rec in records[-args.n:]:
        print(rec.to_json())
    return 0


# -- obs export subcommands -------------------------------------------------

def _find_trace(obs: dict, key: str):
    """Trace row for ``key`` (request_id, else numeric tid)."""
    tid = obs["by_request_id"].get(key)
    if tid is None and key.lstrip("-").isdigit():
        tid = int(key)
    return obs["traces"].get(tid)


def _cmd_trace(args) -> int:
    from repro.serving.obs import load_obs
    obs = load_obs(args.export)
    tr = _find_trace(obs, args.request)
    if tr is None:
        print(f"no trace for {args.request!r} "
              f"({len(obs['traces'])} traces in export)", file=sys.stderr)
        return 1
    label = tr.get("request_id", f"tid {tr['tid']}")
    print(f"request {label}  decision={tr.get('decision', '?')}  "
          f"depth={tr.get('depth')}  latency={tr.get('latency', 0.0):.4f}")
    for part in ("queue_wait", "host_time", "device_time"):
        if part in tr:
            print(f"  {part:<12} {tr[part]:.6f}")
    for s in tr["spans"]:
        attrs = s.get("attrs", {})
        extra = "  " + json.dumps(attrs) if attrs else ""
        print(f"  {s['t0']:10.4f} .. {s['t1']:10.4f}  "
              f"{s['name']:<14}{extra}")
    return 0


def _cmd_why(args) -> int:
    from repro.serving.obs import load_obs
    obs = load_obs(args.export)
    tr = _find_trace(obs, args.request)
    rows = [r for r in obs["audit"]
            if (tr is not None and r.get("tid") == tr["tid"])
            or r.get("request_id") == args.request]
    if tr is None and not rows:
        print(f"no trace or audit rows for {args.request!r}",
              file=sys.stderr)
        return 1
    if tr is not None:
        out = "expired" if tr.get("missed") else "served"
        if tr.get("rejected"):
            out = "rejected"
        print(f"request {tr.get('request_id', tr['tid'])}: {out}  "
              f"decision={tr.get('decision', '?')}  depth={tr.get('depth')}"
              f"  latency={tr.get('latency', 0.0):.4f}")
    for r in rows:
        print(f"  t={r['t']:.4f}  rule={r['rule']}  "
              f"{json.dumps(r.get('detail', {}), sort_keys=True)}")
    if tr is not None and not rows:
        print("  no scheduler rule fired (clean admit)")
    return 0


def _cmd_top(args) -> int:
    from repro.serving.obs import load_obs
    obs = load_obs(args.export)
    traces = list(obs["traces"].values())
    traces.sort(key=lambda t: t.get(args.by, 0.0) or 0.0, reverse=True)
    print(f"{'request':<24} {'decision':<18} {'depth':>5} "
          f"{'latency':>9} {'q_wait':>9} {'device':>9}")
    for tr in traces[:args.n]:
        print(f"{str(tr.get('request_id', tr['tid'])):<24} "
              f"{str(tr.get('decision', '?')):<18} "
              f"{str(tr.get('depth', '?')):>5} "
              f"{tr.get('latency', 0.0):9.4f} "
              f"{tr.get('queue_wait', 0.0):9.4f} "
              f"{tr.get('device_time', 0.0):9.4f}")
    n = len(traces)
    missed = sum(1 for t in traces if t.get("missed"))
    rejected = sum(1 for t in traces if t.get("rejected"))
    print(f"total {n} traced  missed={missed}  rejected={rejected}  "
          f"audit_rows={len(obs['audit'])}  windows={len(obs['windows'])}")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="planectl", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("stats", help="queue depth + per-tenant counters")
    sp.add_argument("journal", help="journal directory")
    sp.add_argument("--json", action="store_true",
                    help="machine-readable output")
    sp.set_defaults(fn=_cmd_stats)

    sp = sub.add_parser("pending",
                        help="request_ids submitted but not terminal "
                             "(exit 1 when any)")
    sp.add_argument("journal")
    sp.set_defaults(fn=_cmd_pending)

    sp = sub.add_parser("tail", help="last N journal records as JSON lines")
    sp.add_argument("journal")
    sp.add_argument("-n", type=int, default=10)
    sp.set_defaults(fn=_cmd_tail)

    sp = sub.add_parser("trace",
                        help="one request's typed spans from an obs export")
    sp.add_argument("export", help="obs JSONL export file")
    sp.add_argument("request", help="request_id or tid")
    sp.set_defaults(fn=_cmd_trace)

    sp = sub.add_parser("why",
                        help="which scheduler rules fired for a request, "
                             "with their inputs")
    sp.add_argument("export")
    sp.add_argument("request")
    sp.set_defaults(fn=_cmd_why)

    sp = sub.add_parser("top", help="worst traced requests + run totals")
    sp.add_argument("export")
    sp.add_argument("-n", type=int, default=10)
    sp.add_argument("--by", default="latency",
                    choices=("latency", "queue_wait", "device_time",
                             "host_time"))
    sp.set_defaults(fn=_cmd_top)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
