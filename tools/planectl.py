"""planectl: offline health/stats over a durable-plane journal.

The journal directory (``repro.serving.plane.Journal``) is the request
plane's source of truth, so this CLI needs no live process — it answers
the operator questions from the segments alone:

    PYTHONPATH=src python tools/planectl.py stats <journal_dir>
    PYTHONPATH=src python tools/planectl.py stats <journal_dir> --json
    PYTHONPATH=src python tools/planectl.py pending <journal_dir>
    PYTHONPATH=src python tools/planectl.py tail <journal_dir> [-n 10]

``stats`` — queue depth (durably submitted, not yet terminal),
per-tenant admit/retire/reject counts, the same breakdown per zoo model
(only when records carry ``model``), journal shape (segments, records,
last seq).  ``pending`` — the request_ids :func:`recover` would redo.
``tail`` — the last N records, one JSON line each.

A live process answers the same questions (plus in-memory queue state)
via ``FrontDoor.stats()``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.serving.plane.health import journal_stats          # noqa: E402
from repro.serving.plane.journal import scan_journal          # noqa: E402


def _cmd_stats(args) -> int:
    st = journal_stats(args.journal)
    if args.json:
        print(json.dumps(st, indent=2, sort_keys=True))
        return 0
    print(f"journal     {st['path']}")
    print(f"version     {st['version']}  source={st['source']}  "
          f"spec={'yes' if st['has_spec'] else 'no'}")
    print(f"segments    {st['segments']}  records={st['records']}  "
          f"last_seq={st['last_seq']}")
    print("counts      " + "  ".join(
        f"{k}={v}" for k, v in sorted(st["counts"].items())))
    print(f"queue_depth {st['queue_depth']}")
    for tenant, c in sorted(st["per_tenant"].items()):
        print(f"  tenant {tenant:<12} submitted={c['submitted']} "
              f"admitted={c['admitted']} staged={c['staged']} "
              f"retired={c['retired']} rejected={c['rejected']} "
              f"pending={c['pending']}")
    for model, c in sorted(st.get("per_model", {}).items()):
        print(f"  model  {model:<12} submitted={c['submitted']} "
              f"admitted={c['admitted']} staged={c['staged']} "
              f"retired={c['retired']} rejected={c['rejected']} "
              f"pending={c['pending']}")
    return 0


def _cmd_pending(args) -> int:
    st = journal_stats(args.journal)
    for rid in st["pending"]:
        print(rid)
    return 0 if not st["pending"] else 1


def _cmd_tail(args) -> int:
    _, records = scan_journal(args.journal)
    for rec in records[-args.n:]:
        print(rec.to_json())
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="planectl", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("stats", help="queue depth + per-tenant counters")
    sp.add_argument("journal", help="journal directory")
    sp.add_argument("--json", action="store_true",
                    help="machine-readable output")
    sp.set_defaults(fn=_cmd_stats)

    sp = sub.add_parser("pending",
                        help="request_ids submitted but not terminal "
                             "(exit 1 when any)")
    sp.add_argument("journal")
    sp.set_defaults(fn=_cmd_pending)

    sp = sub.add_parser("tail", help="last N journal records as JSON lines")
    sp.add_argument("journal")
    sp.add_argument("-n", type=int, default=10)
    sp.set_defaults(fn=_cmd_tail)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
